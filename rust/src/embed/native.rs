//! Native (pure-Rust) implementation of the NOMAD per-block step.
//!
//! This mirrors the Pallas kernel / JAX graph **exactly** (see DESIGN.md §7
//! for the shared math): same analytic gradient decomposition, same
//! mean-over-valid-heads normalization, same masked SGD update.  It is the
//! fallback when no AOT artifact matches a block's bucket, the oracle that
//! the XLA path is cross-checked against, and the CPU performance baseline.
//!
//! # Engines
//!
//! Three implementations of the same gradient coexist:
//!
//! * [`nomad_grad_serial`] — the original single-pass scatter loop, kept
//!   verbatim as the oracle every other path must match to f32
//!   reassociation error (≤1e-5 relative, cross-checked in tests);
//! * [`nomad_grad_scatter`] — the retired chunked parallel path: a private
//!   **full-size** gradient accumulator per [`HEAD_CHUNK`]-head chunk plus a
//!   chunk-ordered reduction.  Demoted to a second oracle and the bench
//!   baseline; its gradient memory traffic is O(size × n_chunks);
//! * [`nomad_grad_gather`] — the production **gather force engine**
//!   (DESIGN.md §9).  Pass 1 walks heads owner-computes: each row writes its
//!   own forces and the per-edge reaction coefficients (no scatter — a head
//!   only ever writes its own row).  Pass 2 gathers the reactions through
//!   CSR transposes of the edge lists ([`ClusterBlock::nbr_in`], built once;
//!   [`ClusterBlock::neg_in`], a counting sort per resample).  Gradient
//!   memory is O(size·(k+negs)) — independent of the chunk count — there is
//!   no reduction pass, and because every row is summed by exactly one owner
//!   in a fixed edge order, the result is bitwise independent of the
//!   worker-thread count *by construction* rather than by careful chunking.
//!   The remote-means table arrives SoA (xs/ys/ws) so the O(R) mean pass
//!   runs on the runtime-dispatched 8-lane microkernels
//!   (`linalg::simd::mean_field` / `mean_repulse` — the same lane
//!   discipline as the distance engine's `simd::dot4`, bitwise identical
//!   with SIMD on or off; DESIGN.md §16).

use super::block::EdgeTranspose;
use super::{ClusterBlock, StepBackend, StepInputs, SyncStepBackend};
use crate::linalg::simd;
use crate::util::parallel::{num_threads, par_for_chunks, par_map, par_rows_mut};
use crate::util::rng::Rng;

/// Heads per parallel chunk of the retired scatter path.  Fixed (not derived
/// from the thread count) so that its chunk-ordered reduction yields
/// identical results on any number of workers.
pub const HEAD_CHUNK: usize = 128;

/// Coordinate rows per task in the scatter path's parallel reduction.
const REDUCE_ROWS: usize = 512;

/// Rows per dynamically claimed task in the gather engine.  Purely a
/// scheduling granule: rows are independent under owner-computes, so the
/// results do not depend on this value (unlike the scatter path, whose
/// chunking *is* its float summation order).
const GATHER_ROWS: usize = 128;

/// Pure-Rust step executor (gather engine).
#[derive(Default)]
pub struct NativeStepBackend {}

impl StepBackend for NativeStepBackend {
    fn step(&self, block: &mut ClusterBlock, inputs: &StepInputs, rng: &mut Rng) -> f64 {
        block.resample_negatives(rng);
        let threads = if inputs.threads == 0 { num_threads() } else { inputs.threads };
        let (grad, loss) = nomad_grad_gather(
            &block.pos,
            &block.nbr_idx,
            &block.nbr_w,
            &block.nbr_in,
            &block.neg_idx,
            &block.neg_in,
            block.neg_w,
            inputs.mean_x,
            inputs.mean_y,
            inputs.mean_w,
            &block.valid,
            block.k,
            block.negs,
            threads,
        );
        let lr = inputs.lr;
        for l in 0..block.n_real {
            block.pos[l * 2] -= lr * grad[l * 2];
            block.pos[l * 2 + 1] -= lr * grad[l * 2 + 1];
        }
        loss
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn as_sync(&self) -> Option<&dyn SyncStepBackend> {
        Some(self)
    }
}

impl SyncStepBackend for NativeStepBackend {}

/// Cauchy kernel q = 1/(1+d²) on 2-d points.
#[inline(always)]
fn q2(ax: f32, ay: f32, bx: f32, by: f32) -> (f32, f32, f32) {
    let dx = ax - bx;
    let dy = ay - by;
    (1.0 / (1.0 + dx * dx + dy * dy), dx, dy)
}

/// Accumulate the unnormalized gradient and loss contributions of heads
/// `lo..hi` into `grad` (full block size).  Shared verbatim by the serial
/// oracle and every scatter-path chunk, so the two cannot drift.
/// Returns `(loss_sum, nvalid)` for the processed range.
fn accumulate_heads(
    lo: usize,
    hi: usize,
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
    grad: &mut [f32],
) -> (f64, f64) {
    let r = mean_w.len();
    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    // scratch buffers hoisted out of the head loop (§Perf iteration 1:
    // per-head Vec allocation dominated the R-heavy profiles); deltas are
    // cached alongside q so the repulsion pass is pure FMA (§Perf iter 3)
    let mut q_ir = vec![0.0f32; r];
    let mut dm = vec![0.0f32; r * 2];
    let mut q_in = vec![0.0f32; negs];

    for i in lo..hi {
        if valid[i] == 0.0 {
            continue;
        }
        nvalid += 1.0;
        let (pix, piy) = (pos[i * 2], pos[i * 2 + 1]);

        // ---- negative mass A_i (means + exact negatives) ----------------
        let mut a = 0.0f32;
        for rr in 0..r {
            let w = mean_w[rr];
            let dx = pix - means[rr * 2];
            let dy = piy - means[rr * 2 + 1];
            let q = 1.0 / (1.0 + dx * dx + dy * dy);
            q_ir[rr] = q;
            dm[rr * 2] = dx;
            dm[rr * 2 + 1] = dy;
            a += w * q;
        }
        for s in 0..negs {
            let nloc = neg_idx[i * negs + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[nloc * 2], pos[nloc * 2 + 1]);
            q_in[s] = q;
            a += neg_w * q;
        }

        // ---- positive edges: loss + attraction + s_i --------------------
        let mut s_i = 0.0f32;
        for s in 0..k {
            let w = nbr_w[i * k + s];
            if w == 0.0 {
                continue;
            }
            let j = nbr_idx[i * k + s] as usize;
            let (q, dx, dy) = q2(pix, piy, pos[j * 2], pos[j * 2 + 1]);
            let z = q + a;
            loss_sum -= (w * (q.ln() - z.ln())) as f64;
            s_i += w / z;
            let c_att = 2.0 * w * q * (1.0 - q / z);
            grad[i * 2] += c_att * dx;
            grad[i * 2 + 1] += c_att * dy;
            grad[j * 2] -= c_att * dx;
            grad[j * 2 + 1] -= c_att * dy;
        }

        if s_i == 0.0 {
            continue;
        }

        // ---- mean repulsion (means are stop-gradient) --------------------
        let mut gx = 0.0f32;
        let mut gy = 0.0f32;
        for rr in 0..r {
            let q = q_ir[rr];
            let c = mean_w[rr] * q * q;
            gx += c * dm[rr * 2];
            gy += c * dm[rr * 2 + 1];
        }
        grad[i * 2] -= 2.0 * s_i * gx;
        grad[i * 2 + 1] -= 2.0 * s_i * gy;

        // ---- exact-negative repulsion (both endpoints move) --------------
        if neg_w != 0.0 {
            for s in 0..negs {
                let nloc = neg_idx[i * negs + s] as usize;
                let q = q_in[s];
                let dx = pix - pos[nloc * 2];
                let dy = piy - pos[nloc * 2 + 1];
                let c = 2.0 * s_i * neg_w * q * q;
                grad[i * 2] -= c * dx;
                grad[i * 2 + 1] -= c * dy;
                grad[nloc * 2] += c * dx;
                grad[nloc * 2 + 1] += c * dy;
            }
        }
    }
    (loss_sum, nvalid)
}

/// Divide by the valid-head count — the mean-normalization all paths share.
fn finalize(mut grad: Vec<f32>, loss_sum: f64, nvalid: f64) -> (Vec<f32>, f64) {
    let inv = 1.0 / nvalid.max(1.0);
    for g in grad.iter_mut() {
        *g = (*g as f64 * inv) as f32;
    }
    // padding rows must not move even if scatter touched them (it cannot:
    // padding never appears as a neighbor/negative of a valid head)
    (grad, loss_sum * inv)
}

/// Assembled, mean-normalized NOMAD gradient for one padded block —
/// **serial oracle**.  Returns `(grad, mean_loss)` where `grad` is
/// size x 2 (padding rows 0).  Mirrors
/// `python/compile/kernels/ref.py::nomad_grad_ref` + `nomad_forces_ref`
/// with the scatter folded in.
pub fn nomad_grad_serial(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> (Vec<f32>, f64) {
    let size = valid.len();
    let mut grad = vec![0.0f32; size * 2];
    let (loss_sum, nvalid) = accumulate_heads(
        0, size, pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, k, negs, &mut grad,
    );
    finalize(grad, loss_sum, nvalid)
}

/// The retired chunked **scatter** path: fixed [`HEAD_CHUNK`]-head chunks
/// with private full-size accumulators, reduced in chunk order.  Kept as a
/// second oracle and the scatter-vs-gather bench baseline — its gradient
/// memory is O(size × n_chunks) where the gather engine's is O(size).
/// `threads` bounds the worker count; the *result* does not depend on it.
/// Falls back to [`nomad_grad_serial`] when the block is a single chunk.
pub fn nomad_grad_scatter(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
    threads: usize,
) -> (Vec<f32>, f64) {
    let size = valid.len();
    let n_chunks = size.div_ceil(HEAD_CHUNK);
    if n_chunks <= 1 {
        return nomad_grad_serial(
            pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, k, negs,
        );
    }
    let threads = threads.max(1).min(n_chunks);

    // per-chunk private accumulators (scatter targets cover the whole
    // block, so each buffer is full-size)
    let partials: Vec<(Vec<f32>, f64, f64)> = par_map(n_chunks, threads, |c| {
        let lo = c * HEAD_CHUNK;
        let hi = (lo + HEAD_CHUNK).min(size);
        let mut g = vec![0.0f32; size * 2];
        let (ls, nv) = accumulate_heads(
            lo, hi, pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, k, negs, &mut g,
        );
        (g, ls, nv)
    });

    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    for (_, ls, nv) in &partials {
        loss_sum += *ls;
        nvalid += *nv;
    }

    // chunk-ordered reduction, parallel over disjoint coordinate ranges
    let mut grad = vec![0.0f32; size * 2];
    par_rows_mut(&mut grad, 2, REDUCE_ROWS, threads, |r0, rows| {
        for (p, _, _) in &partials {
            let src = &p[r0 * 2..r0 * 2 + rows.len()];
            for (d, s) in rows.iter_mut().zip(src) {
                *d += *s;
            }
        }
    });
    finalize(grad, loss_sum, nvalid)
}

/// Gather-engine pass 1 (owner-computes heads `lo..hi`): writes each head's
/// own forces into its row of `grad`, the per-edge attraction reaction
/// coefficients into `c_att`, the per-negative repulsion coefficients into
/// `c_neg`, and the per-head loss into `loss`.  All outputs are local
/// (`lo`-based) zeroed slices — a head never touches another row, so there
/// is no scatter and no race.
fn gather_head_pass(
    lo: usize,
    hi: usize,
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    mean_x: &[f32],
    mean_y: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
    grad: &mut [f32],
    c_att: &mut [f32],
    c_neg: &mut [f32],
    loss: &mut [f64],
) {
    let r = mean_w.len();
    let mut q_ir = vec![0.0f32; r];
    let mut dxr = vec![0.0f32; r];
    let mut dyr = vec![0.0f32; r];
    let mut q_in = vec![0.0f32; negs];

    for i in lo..hi {
        if valid[i] == 0.0 {
            continue;
        }
        let li = i - lo;
        let (pix, piy) = (pos[i * 2], pos[i * 2 + 1]);

        // ---- negative mass A_i (SoA means microkernel + exact negatives) -
        let mut a =
            simd::mean_field(pix, piy, mean_x, mean_y, mean_w, &mut q_ir, &mut dxr, &mut dyr);
        for s in 0..negs {
            let nloc = neg_idx[i * negs + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[nloc * 2], pos[nloc * 2 + 1]);
            q_in[s] = q;
            a += neg_w * q;
        }

        // ---- positive edges: loss + own attraction + s_i + coefficients --
        let mut s_i = 0.0f32;
        let mut loss_i = 0.0f64;
        let (mut gx, mut gy) = (0.0f32, 0.0f32);
        for s in 0..k {
            let w = nbr_w[i * k + s];
            if w == 0.0 {
                continue;
            }
            let j = nbr_idx[i * k + s] as usize;
            let (q, dx, dy) = q2(pix, piy, pos[j * 2], pos[j * 2 + 1]);
            let z = q + a;
            loss_i -= (w * (q.ln() - z.ln())) as f64;
            s_i += w / z;
            let c = 2.0 * w * q * (1.0 - q / z);
            c_att[li * k + s] = c;
            gx += c * dx;
            gy += c * dy;
        }
        loss[li] = loss_i;

        if s_i != 0.0 {
            // ---- mean repulsion (means are stop-gradient, no reaction) ---
            let (mx, my) = simd::mean_repulse(mean_w, &q_ir, &dxr, &dyr);
            gx -= 2.0 * s_i * mx;
            gy -= 2.0 * s_i * my;

            // ---- exact-negative repulsion: own push + coefficient --------
            if neg_w != 0.0 {
                for s in 0..negs {
                    let nloc = neg_idx[i * negs + s] as usize;
                    let q = q_in[s];
                    let dx = pix - pos[nloc * 2];
                    let dy = piy - pos[nloc * 2 + 1];
                    let c = 2.0 * s_i * neg_w * q * q;
                    c_neg[li * negs + s] = c;
                    gx -= c * dx;
                    gy -= c * dy;
                }
            }
        }
        grad[li * 2] = gx;
        grad[li * 2 + 1] = gy;
    }
}

/// Gather-engine pass 2: rows `lo..hi` pull in the reactions of every edge
/// that targets them — attraction reactions through the kNN CSR transpose,
/// repulsion reactions through the negatives transpose — using the
/// coefficients pass 1 published.  `d = pos_head − pos_target` reproduces
/// the scatter path's per-term float values exactly; only the per-row
/// summation order differs.
fn gather_reaction_pass(
    lo: usize,
    hi: usize,
    pos: &[f32],
    nbr_in: &EdgeTranspose,
    neg_in: &EdgeTranspose,
    c_att: &[f32],
    c_neg: &[f32],
    k: usize,
    negs: usize,
    grad: &mut [f32],
) {
    for t in lo..hi {
        let lt = t - lo;
        let (ptx, pty) = (pos[t * 2], pos[t * 2 + 1]);
        let (mut gx, mut gy) = (0.0f32, 0.0f32);
        for &e in nbr_in.incoming(t) {
            let e = e as usize;
            let h = e / k;
            let c = c_att[e];
            gx -= c * (pos[h * 2] - ptx);
            gy -= c * (pos[h * 2 + 1] - pty);
        }
        for &e in neg_in.incoming(t) {
            let e = e as usize;
            let h = e / negs;
            let c = c_neg[e];
            gx += c * (pos[h * 2] - ptx);
            gy += c * (pos[h * 2 + 1] - pty);
        }
        grad[lt * 2] += gx;
        grad[lt * 2 + 1] += gy;
    }
}

/// The **gather force engine** (DESIGN.md §9): mean-normalized NOMAD
/// gradient with no scatter and no reduction.  `nbr_in`/`neg_in` are the
/// CSR transposes of `nbr_idx` (zero-weight slots omitted) and `neg_idx`
/// (all slots) — [`ClusterBlock`] maintains both.  Means are SoA.
///
/// Gradient memory is `size·(2 + k + negs)` floats regardless of the
/// thread count, and the result is bitwise identical for any `threads`
/// because each row is summed by exactly one owner in fixed edge order.
/// Matches [`nomad_grad_serial`] to f32 reassociation error.
pub fn nomad_grad_gather(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    nbr_in: &EdgeTranspose,
    neg_idx: &[i32],
    neg_in: &EdgeTranspose,
    neg_w: f32,
    mean_x: &[f32],
    mean_y: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
    threads: usize,
) -> (Vec<f32>, f64) {
    let size = valid.len();
    debug_assert_eq!(mean_x.len(), mean_w.len());
    debug_assert_eq!(mean_y.len(), mean_w.len());
    debug_assert_eq!(nbr_in.ptr.len(), size + 1);
    debug_assert_eq!(neg_in.ptr.len(), size + 1);
    let threads = threads.max(1);

    let mut grad = vec![0.0f32; size * 2];
    let mut c_att = vec![0.0f32; size * k];
    let mut c_neg = vec![0.0f32; size * negs];
    let mut loss_buf = vec![0.0f64; size];

    // ---- pass 1: owner-computes head pass (writes rows lo..hi only) ------
    {
        let grad_p = grad.as_mut_ptr() as usize;
        let catt_p = c_att.as_mut_ptr() as usize;
        let cneg_p = c_neg.as_mut_ptr() as usize;
        let loss_p = loss_buf.as_mut_ptr() as usize;
        par_for_chunks(size, GATHER_ROWS, threads, |lo, hi| {
            let rows = hi - lo;
            // SAFETY: [lo, hi) row ranges are disjoint across workers
            // (claimed via par_for_chunks' atomic cursor), so the derived
            // subslices never alias; all vectors outlive this call.
            let (grad, c_att, c_neg, loss) = unsafe {
                (
                    std::slice::from_raw_parts_mut((grad_p as *mut f32).add(lo * 2), rows * 2),
                    std::slice::from_raw_parts_mut((catt_p as *mut f32).add(lo * k), rows * k),
                    std::slice::from_raw_parts_mut(
                        (cneg_p as *mut f32).add(lo * negs),
                        rows * negs,
                    ),
                    std::slice::from_raw_parts_mut((loss_p as *mut f64).add(lo), rows),
                )
            };
            gather_head_pass(
                lo, hi, pos, nbr_idx, nbr_w, neg_idx, neg_w, mean_x, mean_y, mean_w, valid, k,
                negs, grad, c_att, c_neg, loss,
            );
        });
    }

    // ---- pass 2: gather the reactions through the transposes --------------
    {
        let grad_p = grad.as_mut_ptr() as usize;
        let c_att_r: &[f32] = &c_att;
        let c_neg_r: &[f32] = &c_neg;
        par_for_chunks(size, GATHER_ROWS, threads, |lo, hi| {
            let rows = hi - lo;
            // SAFETY: as above — disjoint [lo, hi) row ranges.
            let grad = unsafe {
                std::slice::from_raw_parts_mut((grad_p as *mut f32).add(lo * 2), rows * 2)
            };
            gather_reaction_pass(lo, hi, pos, nbr_in, neg_in, c_att_r, c_neg_r, k, negs, grad);
        });
    }

    // fixed-order (row-major) loss fold: thread-count invariant
    let loss_sum: f64 = loss_buf.iter().sum();
    let nvalid = valid.iter().filter(|v| **v != 0.0).count() as f64;
    finalize(grad, loss_sum, nvalid)
}

/// Convenience entry point with the classic AoS signature (interleaved r×2
/// means, no transposes): builds the transposes and the SoA views, then
/// runs the gather engine on the machine's default thread budget.  This is
/// the signature the property tests and ad-hoc callers use; the hot path
/// ([`NativeStepBackend`]) uses the block's precomputed transposes instead.
pub fn nomad_grad(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> (Vec<f32>, f64) {
    let size = valid.len();
    let nbr_in = EdgeTranspose::build(nbr_idx, size, k, |e| nbr_w[e] != 0.0);
    let neg_in = EdgeTranspose::build(neg_idx, size, negs, |_| true);
    let r = mean_w.len();
    let mut mean_x = vec![0.0f32; r];
    let mut mean_y = vec![0.0f32; r];
    for rr in 0..r {
        mean_x[rr] = means[rr * 2];
        mean_y[rr] = means[rr * 2 + 1];
    }
    nomad_grad_gather(
        pos,
        nbr_idx,
        nbr_w,
        &nbr_in,
        neg_idx,
        &neg_in,
        neg_w,
        &mean_x,
        &mean_y,
        mean_w,
        valid,
        k,
        negs,
        num_threads(),
    )
}

/// Scalar NOMAD loss only (no gradient) — used by tests and line searches.
pub fn nomad_loss(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> f64 {
    let size = valid.len();
    let r = mean_w.len();
    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    for i in 0..size {
        if valid[i] == 0.0 {
            continue;
        }
        nvalid += 1.0;
        let (pix, piy) = (pos[i * 2], pos[i * 2 + 1]);
        let mut a = 0.0f32;
        for rr in 0..r {
            let (q, _, _) = q2(pix, piy, means[rr * 2], means[rr * 2 + 1]);
            a += mean_w[rr] * q;
        }
        for s in 0..negs {
            let nloc = neg_idx[i * negs + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[nloc * 2], pos[nloc * 2 + 1]);
            a += neg_w * q;
        }
        for s in 0..k {
            let w = nbr_w[i * k + s];
            if w == 0.0 {
                continue;
            }
            let j = nbr_idx[i * k + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[j * 2], pos[j * 2 + 1]);
            let z = q + a;
            loss_sum -= (w * (q.ln() - z.ln())) as f64;
        }
    }
    loss_sum / nvalid.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a random padded problem mirroring the python test generator.
    pub fn random_problem(
        rng: &mut Rng,
        size: usize,
        k: usize,
        negs: usize,
        r: usize,
        n_real: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>, f32, Vec<f32>, Vec<f32>, Vec<f32>) {
        let pos: Vec<f32> = (0..size * 2).map(|_| rng.normal() * 3.0).collect();
        let mut nbr_idx = vec![0i32; size * k];
        let mut nbr_w = vec![0.0f32; size * k];
        let mut neg_idx = vec![0i32; size * negs];
        for i in 0..size {
            for s in 0..k {
                nbr_idx[i * k + s] = rng.below(n_real.max(1)) as i32;
                nbr_w[i * k + s] = if i < n_real { rng.f32() } else { 0.0 };
            }
            let wsum: f32 = nbr_w[i * k..(i + 1) * k].iter().sum();
            if wsum > 0.0 {
                for s in 0..k {
                    nbr_w[i * k + s] /= wsum;
                }
            }
            for s in 0..negs {
                neg_idx[i * negs + s] =
                    if i < n_real { rng.below(n_real.max(1)) as i32 } else { i as i32 };
            }
        }
        let neg_w = rng.f32() + 0.1;
        let means: Vec<f32> = (0..r * 2).map(|_| rng.normal() * 3.0).collect();
        let mean_w: Vec<f32> = (0..r).map(|_| rng.f32() * 4.0).collect();
        let mut valid = vec![0.0f32; size];
        for v in valid.iter_mut().take(n_real) {
            *v = 1.0;
        }
        (pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid)
    }

    /// Transposes + SoA means for feeding the gather engine directly.
    fn gather_inputs(
        nbr_idx: &[i32],
        nbr_w: &[f32],
        neg_idx: &[i32],
        means: &[f32],
        size: usize,
        k: usize,
        negs: usize,
    ) -> (EdgeTranspose, EdgeTranspose, Vec<f32>, Vec<f32>) {
        let nbr_in = EdgeTranspose::build(nbr_idx, size, k, |e| nbr_w[e] != 0.0);
        let neg_in = EdgeTranspose::build(neg_idx, size, negs, |_| true);
        let mean_x: Vec<f32> = means.iter().step_by(2).copied().collect();
        let mean_y: Vec<f32> = means.iter().skip(1).step_by(2).copied().collect();
        (nbr_in, neg_in, mean_x, mean_y)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::new(0);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 32, 4, 3, 5, 28);
        let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
        let eps = 3e-4f32;
        for probe in [0usize, 5, 11, 23, 54] {
            let mut pp = pos.clone();
            pp[probe] += eps;
            let lp = nomad_loss(&pp, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
            let mut pm = pos.clone();
            pm[probe] -= eps;
            let lm = nomad_loss(&pm, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grad[probe] as f64;
            assert!(
                (fd - an).abs() < 3e-3 * (1.0 + an.abs()),
                "coord {probe}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn padding_rows_have_zero_gradient() {
        let mut rng = Rng::new(1);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 48, 5, 3, 4, 30);
        let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 5, 3);
        for l in 30..48 {
            assert_eq!(grad[l * 2], 0.0);
            assert_eq!(grad[l * 2 + 1], 0.0);
        }
    }

    #[test]
    fn scatter_grad_matches_serial_oracle() {
        let mut rng = Rng::new(11);
        for &(size, k, negs, r, n_real) in
            &[(512usize, 6usize, 4usize, 33usize, 480usize), (384, 5, 3, 17, 300)]
        {
            let (pos, ni, nw, gi, gw, me, mw, va) =
                random_problem(&mut rng, size, k, negs, r, n_real);
            let (gs, ls) = nomad_grad_serial(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, k, negs);
            let (gp, lp) = nomad_grad_scatter(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, k, negs, 4);
            assert!(
                (ls - lp).abs() < 1e-5 * (1.0 + ls.abs()),
                "loss serial {ls} vs scatter {lp}"
            );
            for i in 0..size * 2 {
                let d = (gs[i] - gp[i]).abs();
                assert!(
                    d < 1e-5 * (1.0 + gs[i].abs()),
                    "size {size} coord {i}: serial {} scatter {}",
                    gs[i],
                    gp[i]
                );
            }
            // padding rows stay exactly zero on the scatter path too
            for l in n_real..size {
                assert_eq!(gp[l * 2], 0.0);
                assert_eq!(gp[l * 2 + 1], 0.0);
            }
        }
    }

    #[test]
    fn gather_grad_matches_serial_oracle() {
        let mut rng = Rng::new(21);
        for &(size, k, negs, r, n_real) in &[
            (512usize, 6usize, 4usize, 33usize, 480usize),
            (384, 5, 3, 17, 300),
            (130, 3, 2, 2, 127), // crosses one GATHER_ROWS boundary
        ] {
            let (pos, ni, nw, gi, gw, me, mw, va) =
                random_problem(&mut rng, size, k, negs, r, n_real);
            let (nbr_in, neg_in, mx, my) = gather_inputs(&ni, &nw, &gi, &me, size, k, negs);
            let (gs, ls) = nomad_grad_serial(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, k, negs);
            let (gg, lg) = nomad_grad_gather(
                &pos, &ni, &nw, &nbr_in, &gi, &neg_in, gw, &mx, &my, &mw, &va, k, negs, 4,
            );
            assert!(
                (ls - lg).abs() < 1e-5 * (1.0 + ls.abs()),
                "loss serial {ls} vs gather {lg}"
            );
            for i in 0..size * 2 {
                let d = (gs[i] - gg[i]).abs();
                assert!(
                    d < 1e-5 * (1.0 + gs[i].abs()),
                    "size {size} coord {i}: serial {} gather {}",
                    gs[i],
                    gg[i]
                );
            }
            for l in n_real..size {
                assert_eq!(gg[l * 2], 0.0, "padding row {l} moved");
                assert_eq!(gg[l * 2 + 1], 0.0, "padding row {l} moved");
            }
        }
    }

    #[test]
    fn scatter_grad_invariant_to_thread_count() {
        let mut rng = Rng::new(12);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 512, 6, 4, 20, 500);
        let (g1, l1) = nomad_grad_scatter(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4, 1);
        let (g2, l2) = nomad_grad_scatter(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4, 2);
        let (g8, l8) = nomad_grad_scatter(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4, 8);
        assert_eq!(g1, g2, "1 vs 2 workers must be bitwise identical");
        assert_eq!(g2, g8, "2 vs 8 workers must be bitwise identical");
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(l2.to_bits(), l8.to_bits());
    }

    #[test]
    fn gather_grad_invariant_to_thread_count() {
        let mut rng = Rng::new(13);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 512, 6, 4, 20, 500);
        let (nbr_in, neg_in, mx, my) = gather_inputs(&ni, &nw, &gi, &me, 512, 6, 4);
        let run = |threads| {
            nomad_grad_gather(
                &pos, &ni, &nw, &nbr_in, &gi, &neg_in, gw, &mx, &my, &mw, &va, 6, 4, threads,
            )
        };
        let (g1, l1) = run(1);
        let (g2, l2) = run(2);
        let (g8, l8) = run(8);
        assert_eq!(g1, g2, "1 vs 2 workers must be bitwise identical");
        assert_eq!(g2, g8, "2 vs 8 workers must be bitwise identical");
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(l2.to_bits(), l8.to_bits());
    }

    #[test]
    fn steps_reduce_loss() {
        let mut rng = Rng::new(2);
        let (mut pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 64, 6, 4, 6, 64);
        let l0 = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
        for _ in 0..20 {
            let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
            for (p, g) in pos.iter_mut().zip(&grad) {
                *p -= 3.0 * g;
            }
        }
        let l1 = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
        assert!(l1 < l0, "loss {l0} -> {l1}");
    }

    #[test]
    fn loss_invariant_under_padding_growth() {
        let mut rng = Rng::new(3);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 32, 4, 3, 5, 32);
        let l = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
        // grow to 64 with padding
        let mut pos2 = pos.clone();
        pos2.extend(std::iter::repeat(0.0).take(64));
        let mut ni2 = ni.clone();
        let mut nw2 = nw.clone();
        let mut gi2 = gi.clone();
        let mut va2 = va.clone();
        for l2 in 32..64 {
            for _ in 0..4 {
                ni2.push(l2 as i32);
                nw2.push(0.0);
            }
            for _ in 0..3 {
                gi2.push(l2 as i32);
            }
            va2.push(0.0);
        }
        let lp = nomad_loss(&pos2, &ni2, &nw2, &gi2, gw, &me, &mw, &va2, 4, 3);
        assert!((l - lp).abs() < 1e-9);
    }
}
