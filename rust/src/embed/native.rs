//! Native (pure-Rust) implementation of the NOMAD per-block step.
//!
//! This mirrors the Pallas kernel / JAX graph **exactly** (see DESIGN.md §7
//! for the shared math): same analytic gradient decomposition, same
//! mean-over-valid-heads normalization, same masked SGD update.  It is the
//! fallback when no AOT artifact matches a block's bucket, the oracle that
//! the XLA path is cross-checked against, and the CPU performance baseline.

use super::{ClusterBlock, StepBackend, StepInputs};
use crate::util::rng::Rng;

/// Pure-Rust step executor.
#[derive(Default)]
pub struct NativeStepBackend {}

impl StepBackend for NativeStepBackend {
    fn step(&self, block: &mut ClusterBlock, inputs: &StepInputs, rng: &mut Rng) -> f64 {
        block.resample_negatives(rng);
        let (grad, loss) = nomad_grad(
            &block.pos,
            &block.nbr_idx,
            &block.nbr_w,
            &block.neg_idx,
            block.neg_w,
            inputs.means,
            inputs.mean_w,
            &block.valid,
            block.k,
            block.negs,
        );
        let lr = inputs.lr;
        for l in 0..block.n_real {
            block.pos[l * 2] -= lr * grad[l * 2];
            block.pos[l * 2 + 1] -= lr * grad[l * 2 + 1];
        }
        loss
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Cauchy kernel q = 1/(1+d²) on 2-d points.
#[inline(always)]
fn q2(ax: f32, ay: f32, bx: f32, by: f32) -> (f32, f32, f32) {
    let dx = ax - bx;
    let dy = ay - by;
    (1.0 / (1.0 + dx * dx + dy * dy), dx, dy)
}

/// Assembled, mean-normalized NOMAD gradient for one padded block.
///
/// Returns `(grad, mean_loss)` where `grad` is size x 2 (padding rows 0).
/// Mirrors `python/compile/kernels/ref.py::nomad_grad_ref` +
/// `nomad_forces_ref` with the scatter folded in.
#[allow(clippy::too_many_arguments)]
pub fn nomad_grad(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> (Vec<f32>, f64) {
    let size = valid.len();
    let r = mean_w.len();
    let mut grad = vec![0.0f32; size * 2];
    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    // scratch buffers hoisted out of the head loop (§Perf iteration 1:
    // per-head Vec allocation dominated the R-heavy profiles); deltas are
    // cached alongside q so the repulsion pass is pure FMA (§Perf iter 3)
    let mut q_ir = vec![0.0f32; r];
    let mut dm = vec![0.0f32; r * 2];
    let mut q_in = vec![0.0f32; negs];

    for i in 0..size {
        if valid[i] == 0.0 {
            continue;
        }
        nvalid += 1.0;
        let (pix, piy) = (pos[i * 2], pos[i * 2 + 1]);

        // ---- negative mass A_i (means + exact negatives) ----------------
        let mut a = 0.0f32;
        for rr in 0..r {
            let w = mean_w[rr];
            let dx = pix - means[rr * 2];
            let dy = piy - means[rr * 2 + 1];
            let q = 1.0 / (1.0 + dx * dx + dy * dy);
            q_ir[rr] = q;
            dm[rr * 2] = dx;
            dm[rr * 2 + 1] = dy;
            a += w * q;
        }
        for s in 0..negs {
            let nloc = neg_idx[i * negs + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[nloc * 2], pos[nloc * 2 + 1]);
            q_in[s] = q;
            a += neg_w * q;
        }

        // ---- positive edges: loss + attraction + s_i --------------------
        let mut s_i = 0.0f32;
        for s in 0..k {
            let w = nbr_w[i * k + s];
            if w == 0.0 {
                continue;
            }
            let j = nbr_idx[i * k + s] as usize;
            let (q, dx, dy) = q2(pix, piy, pos[j * 2], pos[j * 2 + 1]);
            let z = q + a;
            loss_sum -= (w * (q.ln() - z.ln())) as f64;
            s_i += w / z;
            let c_att = 2.0 * w * q * (1.0 - q / z);
            grad[i * 2] += c_att * dx;
            grad[i * 2 + 1] += c_att * dy;
            grad[j * 2] -= c_att * dx;
            grad[j * 2 + 1] -= c_att * dy;
        }

        if s_i == 0.0 {
            continue;
        }

        // ---- mean repulsion (means are stop-gradient) --------------------
        let mut gx = 0.0f32;
        let mut gy = 0.0f32;
        for rr in 0..r {
            let q = q_ir[rr];
            let c = mean_w[rr] * q * q;
            gx += c * dm[rr * 2];
            gy += c * dm[rr * 2 + 1];
        }
        grad[i * 2] -= 2.0 * s_i * gx;
        grad[i * 2 + 1] -= 2.0 * s_i * gy;

        // ---- exact-negative repulsion (both endpoints move) --------------
        if neg_w != 0.0 {
            for s in 0..negs {
                let nloc = neg_idx[i * negs + s] as usize;
                let q = q_in[s];
                let dx = pix - pos[nloc * 2];
                let dy = piy - pos[nloc * 2 + 1];
                let c = 2.0 * s_i * neg_w * q * q;
                grad[i * 2] -= c * dx;
                grad[i * 2 + 1] -= c * dy;
                grad[nloc * 2] += c * dx;
                grad[nloc * 2 + 1] += c * dy;
            }
        }
    }

    let inv = 1.0 / nvalid.max(1.0);
    for g in grad.iter_mut() {
        *g = (*g as f64 * inv) as f32;
    }
    // padding rows must not move even if scatter touched them (it cannot:
    // padding never appears as a neighbor/negative of a valid head)
    (grad, loss_sum * inv)
}

/// Scalar NOMAD loss only (no gradient) — used by tests and line searches.
#[allow(clippy::too_many_arguments)]
pub fn nomad_loss(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> f64 {
    let size = valid.len();
    let r = mean_w.len();
    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    for i in 0..size {
        if valid[i] == 0.0 {
            continue;
        }
        nvalid += 1.0;
        let (pix, piy) = (pos[i * 2], pos[i * 2 + 1]);
        let mut a = 0.0f32;
        for rr in 0..r {
            let (q, _, _) = q2(pix, piy, means[rr * 2], means[rr * 2 + 1]);
            a += mean_w[rr] * q;
        }
        for s in 0..negs {
            let nloc = neg_idx[i * negs + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[nloc * 2], pos[nloc * 2 + 1]);
            a += neg_w * q;
        }
        for s in 0..k {
            let w = nbr_w[i * k + s];
            if w == 0.0 {
                continue;
            }
            let j = nbr_idx[i * k + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[j * 2], pos[j * 2 + 1]);
            let z = q + a;
            loss_sum -= (w * (q.ln() - z.ln())) as f64;
        }
    }
    loss_sum / nvalid.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a random padded problem mirroring the python test generator.
    pub fn random_problem(
        rng: &mut Rng,
        size: usize,
        k: usize,
        negs: usize,
        r: usize,
        n_real: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>, f32, Vec<f32>, Vec<f32>, Vec<f32>) {
        let pos: Vec<f32> = (0..size * 2).map(|_| rng.normal() * 3.0).collect();
        let mut nbr_idx = vec![0i32; size * k];
        let mut nbr_w = vec![0.0f32; size * k];
        let mut neg_idx = vec![0i32; size * negs];
        for i in 0..size {
            for s in 0..k {
                nbr_idx[i * k + s] = rng.below(n_real.max(1)) as i32;
                nbr_w[i * k + s] = if i < n_real { rng.f32() } else { 0.0 };
            }
            let wsum: f32 = nbr_w[i * k..(i + 1) * k].iter().sum();
            if wsum > 0.0 {
                for s in 0..k {
                    nbr_w[i * k + s] /= wsum;
                }
            }
            for s in 0..negs {
                neg_idx[i * negs + s] =
                    if i < n_real { rng.below(n_real.max(1)) as i32 } else { i as i32 };
            }
        }
        let neg_w = rng.f32() + 0.1;
        let means: Vec<f32> = (0..r * 2).map(|_| rng.normal() * 3.0).collect();
        let mean_w: Vec<f32> = (0..r).map(|_| rng.f32() * 4.0).collect();
        let mut valid = vec![0.0f32; size];
        for v in valid.iter_mut().take(n_real) {
            *v = 1.0;
        }
        (pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::new(0);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 32, 4, 3, 5, 28);
        let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
        let eps = 3e-4f32;
        for probe in [0usize, 5, 11, 23, 54] {
            let mut pp = pos.clone();
            pp[probe] += eps;
            let lp = nomad_loss(&pp, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
            let mut pm = pos.clone();
            pm[probe] -= eps;
            let lm = nomad_loss(&pm, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grad[probe] as f64;
            assert!(
                (fd - an).abs() < 3e-3 * (1.0 + an.abs()),
                "coord {probe}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn padding_rows_have_zero_gradient() {
        let mut rng = Rng::new(1);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 48, 5, 3, 4, 30);
        let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 5, 3);
        for l in 30..48 {
            assert_eq!(grad[l * 2], 0.0);
            assert_eq!(grad[l * 2 + 1], 0.0);
        }
    }

    #[test]
    fn steps_reduce_loss() {
        let mut rng = Rng::new(2);
        let (mut pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 64, 6, 4, 6, 64);
        let l0 = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
        for _ in 0..20 {
            let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
            for (p, g) in pos.iter_mut().zip(&grad) {
                *p -= 3.0 * g;
            }
        }
        let l1 = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
        assert!(l1 < l0, "loss {l0} -> {l1}");
    }

    #[test]
    fn loss_invariant_under_padding_growth() {
        let mut rng = Rng::new(3);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 32, 4, 3, 5, 32);
        let l = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
        // grow to 64 with padding
        let mut pos2 = pos.clone();
        pos2.extend(std::iter::repeat(0.0).take(64));
        let mut ni2 = ni.clone();
        let mut nw2 = nw.clone();
        let mut gi2 = gi.clone();
        let mut va2 = va.clone();
        for l2 in 32..64 {
            for _ in 0..4 {
                ni2.push(l2 as i32);
                nw2.push(0.0);
            }
            for _ in 0..3 {
                gi2.push(l2 as i32);
            }
            va2.push(0.0);
        }
        let lp = nomad_loss(&pos2, &ni2, &nw2, &gi2, gw, &me, &mw, &va2, 4, 3);
        assert!((l - lp).abs() < 1e-9);
    }
}
