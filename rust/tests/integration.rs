//! Cross-layer integration: the AOT XLA artifacts must agree numerically
//! with the native Rust implementations (which themselves are validated
//! against jax.grad in the python test suite — closing the loop L1↔L2↔L3).
//!
//! Requires `make artifacts`; tests skip (with a notice) when absent.
//! The whole file is compiled only with the `xla` cargo feature — the
//! default offline build has no PJRT runtime to integrate against.

#![cfg(feature = "xla")]

use nomad::ann::backend::{AnnBackend, NativeBackend};
use nomad::ann::graph::{edge_weights, WeightModel};
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use nomad::data::gaussian_mixture;
use nomad::embed::native::NativeStepBackend;
use nomad::embed::{ClusterBlock, NomadParams, StepBackend, StepInputs};
use nomad::linalg::Matrix;
use nomad::runtime::{XlaAnnBackend, XlaStepBackend};
use nomad::util::rng::Rng;

fn artifacts_available() -> bool {
    let ok = nomad::runtime::artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts`");
    }
    ok
}

/// Build one real block from a small dataset (means SoA, as StepInputs wants).
fn make_block(seed: u64, n: usize) -> (ClusterBlock, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let ds = gaussian_mixture(n, 16, 3, 8.0, 0.3, 0.5, &mut rng);
    let idx = ClusterIndex::build(
        &ds.x,
        &IndexParams { n_clusters: 3, k: 15, ..Default::default() },
        &NativeBackend::default(),
        &mut rng,
    );
    let ew = edge_weights(&idx, WeightModel::InverseRankPaper);
    let init: Vec<f32> = (0..n * 2).map(|_| rng.normal()).collect();
    let block = ClusterBlock::build(&idx, &ew, 0, &init, n, 5.0, 8);
    // means of the other clusters
    let mut mean_x = Vec::new();
    let mut mean_y = Vec::new();
    let mut mean_w = Vec::new();
    for c in 1..idx.n_clusters() {
        let b = ClusterBlock::build(&idx, &ew, c, &init, n, 5.0, 8);
        let m = b.mean();
        mean_x.push(m[0]);
        mean_y.push(m[1]);
        mean_w.push(b.mean_weight(n, 5.0));
    }
    (block, mean_x, mean_y, mean_w)
}

#[test]
fn xla_step_matches_native_step() {
    if !artifacts_available() {
        return;
    }
    let (block0, mean_x, mean_y, mean_w) = make_block(0, 600);
    let inputs =
        StepInputs { mean_x: &mean_x, mean_y: &mean_y, mean_w: &mean_w, lr: 2.0, threads: 1 };

    let xla = XlaStepBackend::from_env().expect("xla backend");
    let native = NativeStepBackend::default();

    // identical negative samples: same fork seed for both backends
    let mut b_native = block0.clone();
    let mut b_xla = block0.clone();
    let mut rng1 = Rng::new(99);
    let mut rng2 = Rng::new(99);
    let l_native = native.step(&mut b_native, &inputs, &mut rng1);
    let l_xla = xla.step(&mut b_xla, &inputs, &mut rng2);

    assert!(
        (l_native - l_xla).abs() < 1e-4 * (1.0 + l_native.abs()),
        "loss native {l_native} vs xla {l_xla}"
    );
    let mut max_err = 0.0f32;
    for i in 0..b_native.n_real * 2 {
        let e = (b_native.pos[i] - b_xla.pos[i]).abs();
        max_err = max_err.max(e);
    }
    assert!(max_err < 1e-3, "max position err {max_err}");
}

#[test]
fn xla_step_multiple_epochs_stays_close() {
    if !artifacts_available() {
        return;
    }
    let (block0, mean_x, mean_y, mean_w) = make_block(1, 400);
    let inputs =
        StepInputs { mean_x: &mean_x, mean_y: &mean_y, mean_w: &mean_w, lr: 1.0, threads: 1 };
    let xla = XlaStepBackend::from_env().unwrap();
    let native = NativeStepBackend::default();
    let mut b_native = block0.clone();
    let mut b_xla = block0;
    for step in 0..5 {
        let mut rng1 = Rng::new(1000 + step);
        let mut rng2 = Rng::new(1000 + step);
        native.step(&mut b_native, &inputs, &mut rng1);
        xla.step(&mut b_xla, &inputs, &mut rng2);
    }
    let mut max_err = 0.0f32;
    for i in 0..b_native.n_real * 2 {
        max_err = max_err.max((b_native.pos[i] - b_xla.pos[i]).abs());
    }
    assert!(max_err < 5e-3, "5-step drift {max_err}");
}

#[test]
fn xla_ann_assign_matches_native() {
    if !artifacts_available() {
        return;
    }
    let mut rng = Rng::new(2);
    let ds = gaussian_mixture(700, 64, 6, 10.0, 0.2, 0.5, &mut rng);
    let mut cent = Matrix::zeros(6, 64);
    for c in 0..6 {
        let r = rng.below(700);
        cent.row_mut(c).copy_from_slice(ds.x.row(r));
    }
    let xla = XlaAnnBackend::from_env().unwrap();
    let native = NativeBackend::default();
    let a1 = xla.assign(&ds.x, &cent);
    let a2 = native.assign(&ds.x, &cent);
    let mut mismatched = 0;
    for i in 0..700 {
        if a1[i].0 != a2[i].0 {
            // ties allowed: distances must then be equal
            assert!(
                (a1[i].1 - a2[i].1).abs() < 1e-2 * (1.0 + a2[i].1.abs()),
                "row {i}: xla {:?} native {:?}",
                a1[i],
                a2[i]
            );
            mismatched += 1;
        } else {
            assert!((a1[i].1 - a2[i].1).abs() < 1e-2 * (1.0 + a2[i].1.abs()));
        }
    }
    assert!(mismatched < 10, "{mismatched} tie mismatches");
}

#[test]
fn xla_ann_knn_matches_native() {
    if !artifacts_available() {
        return;
    }
    let mut rng = Rng::new(3);
    let ds = gaussian_mixture(300, 64, 2, 6.0, 0.0, 0.3, &mut rng);
    let xla = XlaAnnBackend::from_env().unwrap();
    let native = NativeBackend::default();
    let k = 15;
    let (_, d1) = xla.knn(&ds.x, k);
    let (_, d2_) = native.knn(&ds.x, k);
    for i in 0..300 * k {
        let (a, b) = (d1[i], d2_[i]);
        if a.is_finite() || b.is_finite() {
            assert!(
                (a - b).abs() < 1e-2 * (1.0 + b.abs()),
                "slot {i}: xla {a} native {b}"
            );
        }
    }
}

#[test]
fn coordinator_runs_on_xla_backend() {
    if !artifacts_available() {
        return;
    }
    let mut rng = Rng::new(4);
    let ds = gaussian_mixture(500, 16, 4, 10.0, 0.2, 0.5, &mut rng);
    let params = NomadParams { epochs: 8, k: 15, negs: 8, ..Default::default() };
    let coord = NomadCoordinator::new(
        params,
        RunConfig {
            n_devices: 2,
            backend: BackendKind::Xla,
            index: IndexParams { n_clusters: 4, k: 15, ..Default::default() },
            ..Default::default()
        },
    );
    let run = coord.fit(&ds, &NativeBackend::default());
    assert_eq!(run.positions.rows, 500);
    assert!(run.loss_history.iter().all(|l| l.is_finite()));
    let first = run.loss_history.first().unwrap();
    let last = run.loss_history.last().unwrap();
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn native_and_xla_full_runs_agree_statistically() {
    if !artifacts_available() {
        return;
    }
    let mut rng = Rng::new(5);
    let ds = gaussian_mixture(400, 16, 3, 12.0, 0.1, 0.4, &mut rng);
    let params = NomadParams { epochs: 12, k: 15, negs: 8, seed: 7, ..Default::default() };
    let mk = |backend| {
        NomadCoordinator::new(
            params.clone(),
            RunConfig {
                n_devices: 1,
                backend,
                index: IndexParams { n_clusters: 3, k: 15, ..Default::default() },
                ..Default::default()
            },
        )
    };
    let run_n = mk(BackendKind::Native).fit(&ds, &NativeBackend::default());
    let run_x = mk(BackendKind::Xla).fit(&ds, &NativeBackend::default());
    // same seed, same negative sampling order within each device -> final
    // loss should agree tightly
    let ln = run_n.loss_history.last().unwrap();
    let lx = run_x.loss_history.last().unwrap();
    assert!(
        (ln - lx).abs() < 5e-3 * (1.0 + ln.abs()),
        "final losses diverged: native {ln} xla {lx}"
    );
}
