//! Property-based tests (hand-rolled generators over the crate PRNG; the
//! offline environment has no proptest).  Each property runs across many
//! random cases with printable failing seeds.

use nomad::ann::backend::{AnnBackend, NativeBackend};
use nomad::data::gaussian_mixture;
use nomad::distributed::sharder::{imbalance, shard_clusters};
use nomad::embed::block::bucket_for;
use nomad::embed::native::{nomad_grad, nomad_loss};
use nomad::embed::sgd::LrSchedule;
use nomad::linalg::Matrix;
use nomad::util::json::Json;
use nomad::util::rng::Rng;

const CASES: usize = 40;

fn rand_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth > 2 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.f32() < 0.5),
        2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
        3 => {
            let len = rng.below(12);
            Json::Str((0..len).map(|_| char::from(32 + rng.below(94) as u8)).collect())
        }
        4 => {
            let len = rng.below(5);
            Json::Arr((0..len).map(|_| rand_json(rng, depth + 1)).collect())
        }
        _ => {
            let len = rng.below(5);
            Json::Obj(
                (0..len)
                    .map(|i| (format!("k{i}_{}", rng.below(100)), rand_json(rng, depth + 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let v = rand_json(&mut rng, 0);
        let parsed = Json::parse(&v.to_string())
            .unwrap_or_else(|e| panic!("seed {seed}: {e} on {}", v.to_string()));
        assert_eq!(parsed, v, "seed {seed}");
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(pretty, v, "seed {seed} (pretty)");
    }
}

#[test]
fn prop_sharder_partitions_and_balances() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let n_clusters = 1 + rng.below(40);
        let devices = 1 + rng.below(10);
        let sizes: Vec<usize> = (0..n_clusters).map(|_| 1 + rng.below(1000)).collect();
        let shards = shard_clusters(&sizes, devices);
        let mut seen = vec![false; n_clusters];
        for s in &shards {
            for &c in s {
                assert!(!seen[c], "seed {seed}: cluster {c} twice");
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&v| v), "seed {seed}: cluster missing");
        // LPT bound: max load <= mean + max_item
        let loads: Vec<usize> = shards.iter().map(|s| s.iter().map(|&c| sizes[c]).sum()).collect();
        let total: usize = sizes.iter().sum();
        let max_item = *sizes.iter().max().unwrap();
        let bound = total / devices + max_item;
        assert!(
            *loads.iter().max().unwrap() <= bound,
            "seed {seed}: load {} > bound {bound}",
            loads.iter().max().unwrap()
        );
        let _ = imbalance(&sizes, &shards);
    }
}

#[test]
fn prop_native_gradient_matches_finite_differences() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed);
        let size = 16 + rng.below(32);
        let n_real = 1 + rng.below(size);
        let k = 1 + rng.below(6);
        let negs = 1 + rng.below(4);
        let r = 1 + rng.below(8);

        let pos: Vec<f32> = (0..size * 2).map(|_| rng.normal() * 2.0).collect();
        let mut nbr_idx = vec![0i32; size * k];
        let mut nbr_w = vec![0.0f32; size * k];
        let mut neg_idx = vec![0i32; size * negs];
        for i in 0..size {
            for s in 0..k {
                nbr_idx[i * k + s] = rng.below(n_real) as i32;
                nbr_w[i * k + s] = if i < n_real { rng.f32() } else { 0.0 };
            }
            for s in 0..negs {
                neg_idx[i * negs + s] = if i < n_real { rng.below(n_real) as i32 } else { i as i32 };
            }
        }
        let neg_w = rng.f32() + 0.05;
        let means: Vec<f32> = (0..r * 2).map(|_| rng.normal() * 2.0).collect();
        let mean_w: Vec<f32> = (0..r).map(|_| rng.f32() * 3.0).collect();
        let mut valid = vec![0.0f32; size];
        for v in valid.iter_mut().take(n_real) {
            *v = 1.0;
        }

        let (grad, _) =
            nomad_grad(&pos, &nbr_idx, &nbr_w, &neg_idx, neg_w, &means, &mean_w, &valid, k, negs);
        // probe a few coordinates
        for probe in 0..3 {
            let c = rng.below(n_real * 2);
            let eps = 2e-3f32;
            let mut pp = pos.clone();
            pp[c] += eps;
            let lp = nomad_loss(&pp, &nbr_idx, &nbr_w, &neg_idx, neg_w, &means, &mean_w, &valid, k, negs);
            let mut pm = pos.clone();
            pm[c] -= eps;
            let lm = nomad_loss(&pm, &nbr_idx, &nbr_w, &neg_idx, neg_w, &means, &mean_w, &valid, k, negs);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grad[c] as f64;
            assert!(
                (fd - an).abs() < 5e-2 * (1.0 + an.abs().max(fd.abs())),
                "seed {seed} probe {probe} coord {c}: fd {fd} vs analytic {an}"
            );
        }
    }
}

#[test]
fn prop_kmeans_assignment_is_argmin() {
    let be = NativeBackend::default();
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let n = 20 + rng.below(100);
        let d = 2 + rng.below(16);
        let c = 2 + rng.below(10);
        let mut x = Matrix::zeros(n, d);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        let mut cent = Matrix::zeros(c, d);
        for v in cent.data.iter_mut() {
            *v = rng.normal();
        }
        for (i, (a, dist)) in be.assign(&x, &cent).into_iter().enumerate() {
            let _ = a;
            for j in 0..c {
                let dj = nomad::linalg::d2(x.row(i), cent.row(j));
                assert!(
                    dist <= dj + 1e-4,
                    "seed {seed} row {i}: assigned at {dist} but {j} at {dj}"
                );
            }
        }
    }
}

#[test]
fn prop_knn_distances_sorted_and_consistent() {
    let be = NativeBackend::default();
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let n = 5 + rng.below(60);
        let d = 2 + rng.below(8);
        let k = 1 + rng.below(8);
        let mut x = Matrix::zeros(n, d);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        let (idx, dd) = be.knn(&x, k);
        for i in 0..n {
            for s in 0..k {
                let j = idx[i * k + s];
                if j == u32::MAX {
                    assert!(s >= n - 1, "seed {seed}: premature padding");
                    continue;
                }
                assert_ne!(j as usize, i);
                let real = nomad::linalg::d2(x.row(i), x.row(j as usize));
                assert!((real - dd[i * k + s]).abs() < 1e-3);
                if s > 0 && dd[i * k + s - 1].is_finite() {
                    assert!(dd[i * k + s - 1] <= dd[i * k + s] + 1e-6);
                }
            }
        }
    }
}

#[test]
fn prop_bucket_for_is_minimal_cover() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(20_000);
        let b = bucket_for(n);
        assert!(b >= n, "bucket {b} < {n}");
        // minimality among the bucket set
        for cand in nomad::embed::block::STEP_BUCKETS {
            if cand >= n {
                assert!(b <= cand, "bucket {b} not minimal for {n} (cand {cand})");
                break;
            }
        }
    }
}

#[test]
fn prop_lr_schedule_monotone_nonnegative() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let epochs = 1 + rng.below(500);
        let s = LrSchedule { initial: rng.f64() * 1000.0, epochs };
        let mut prev = f64::INFINITY;
        for e in 0..epochs + 2 {
            let lr = s.at(e);
            assert!(lr >= 0.0 && lr <= s.initial + 1e-12, "seed {seed}");
            assert!(lr <= prev + 1e-12, "seed {seed}: lr not decreasing");
            prev = lr;
        }
    }
}

#[test]
fn prop_loss_decreases_under_descent_on_real_clusters() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed);
        let ds = gaussian_mixture(200 + rng.below(200), 8, 3, 8.0, 0.2, 0.5, &mut rng);
        let idx = nomad::ann::ClusterIndex::build(
            &ds.x,
            &nomad::ann::IndexParams { n_clusters: 3, k: 5, ..Default::default() },
            &NativeBackend::default(),
            &mut rng,
        );
        let ew = nomad::ann::graph::edge_weights(
            &idx,
            nomad::ann::graph::WeightModel::InverseRankForward,
        );
        let init: Vec<f32> = (0..ds.n() * 2).map(|_| rng.normal()).collect();
        let mut block = nomad::embed::ClusterBlock::build(&idx, &ew, 0, &init, ds.n(), 5.0, 4);
        block.resample_negatives(&mut rng);
        let means = vec![0.0f32, 0.0];
        let mean_w = vec![1.0f32];
        let l0 = nomad_loss(
            &block.pos, &block.nbr_idx, &block.nbr_w, &block.neg_idx, block.neg_w,
            &means, &mean_w, &block.valid, block.k, block.negs,
        );
        for _ in 0..15 {
            let (grad, _) = nomad_grad(
                &block.pos, &block.nbr_idx, &block.nbr_w, &block.neg_idx, block.neg_w,
                &means, &mean_w, &block.valid, block.k, block.negs,
            );
            for (p, g) in block.pos.iter_mut().zip(&grad) {
                *p -= 5.0 * g;
            }
        }
        let l1 = nomad_loss(
            &block.pos, &block.nbr_idx, &block.nbr_w, &block.neg_idx, block.neg_w,
            &means, &mean_w, &block.valid, block.k, block.negs,
        );
        assert!(l1 < l0, "seed {seed}: {l0} -> {l1}");
    }
}

#[test]
fn prop_npy_roundtrip_random_shapes() {
    let dir = std::env::temp_dir().join("nomad_prop_npy");
    std::fs::create_dir_all(&dir).unwrap();
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let shape = if rng.f32() < 0.5 {
            vec![1 + rng.below(50)]
        } else {
            vec![1 + rng.below(30), 1 + rng.below(30)]
        };
        let count: usize = shape.iter().product();
        let data: Vec<f32> = (0..count).map(|_| rng.normal()).collect();
        let t = nomad::util::npy::NpyF32::new(shape, data);
        let p = dir.join(format!("p{seed}.npy"));
        t.save(&p).unwrap();
        assert_eq!(nomad::util::npy::NpyF32::load(&p).unwrap(), t, "seed {seed}");
    }
}
