//! Property tests for the tiled norm-trick distance engine (DESIGN.md §8):
//! the tiled paths must match the naive oracles **exactly** — ties broken
//! identically — and be bitwise invariant to the worker count.
//!
//! Exactness strategy: most cases use matrices of small-integer values.
//! There, every dot product, norm, and squared distance is an exact f32
//! integer in both the naive `Σ(a−b)²` and the tiled `‖a‖²+‖b‖²−2⟨a,b⟩`
//! formulations, so equality (including every tie outcome) is guaranteed
//! by construction rather than by luck — and low-cardinality integer data
//! is riddled with duplicate rows and genuinely tied distances, which
//! exercises the `(d², index)` contract for real.  Shapes are drawn
//! ragged on purpose: n, m, d deliberately straddle the tile constants.

use nomad::ann::backend::{assign_naive, knn_naive, AnnBackend, NativeBackend};
use nomad::ann::knn::{exact_global, exact_global_naive, within_clusters, within_clusters_naive};
use nomad::linalg::distance::{assign_tiled, self_knn_tiled, TILE_C, TILE_Q};
use nomad::linalg::Matrix;
use nomad::util::rng::Rng;

const CASES: usize = 25;

/// Matrix of uniform integers in [0, hi) stored as f32 — exact arithmetic
/// in both distance formulations.
fn int_matrix(rng: &mut Rng, n: usize, d: usize, hi: usize) -> Matrix {
    let mut m = Matrix::zeros(n, d);
    for v in m.data.iter_mut() {
        *v = rng.below(hi) as f32;
    }
    m
}

fn gauss_matrix(rng: &mut Rng, n: usize, d: usize) -> Matrix {
    let mut m = Matrix::zeros(n, d);
    for v in m.data.iter_mut() {
        *v = rng.normal();
    }
    m
}

/// Ragged dimension draw: sizes cross the given tile boundary about half
/// the time and are rarely aligned to it.
fn ragged(rng: &mut Rng, tile: usize) -> usize {
    1 + rng.below(2 * tile + 5)
}

#[test]
fn prop_assign_tiled_matches_naive_exactly() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let n = ragged(&mut rng, TILE_Q);
        let m = ragged(&mut rng, TILE_C);
        let d = 1 + rng.below(40);
        let x = int_matrix(&mut rng, n, d, 6);
        let c = int_matrix(&mut rng, m, d, 6);
        for threads in [1usize, 3] {
            let tiled = assign_tiled(&x, &c, threads);
            let naive = assign_naive(&x, &c);
            assert_eq!(tiled, naive, "seed {seed} n {n} m {m} d {d} threads {threads}");
        }
    }
}

#[test]
fn prop_knn_tiled_matches_naive_exactly() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(100 + seed);
        let n = 2 + rng.below(2 * TILE_C + 9);
        let d = 1 + rng.below(33);
        // k straddles the insertion/heap crossover (16)
        let k = 1 + rng.below(24);
        let x = int_matrix(&mut rng, n, d, 5);
        for threads in [1usize, 4] {
            let (ti, td) = self_knn_tiled(&x, k, threads);
            let (ni, nd) = knn_naive(&x, k);
            assert_eq!(ti, ni, "idx: seed {seed} n {n} d {d} k {k} threads {threads}");
            assert_eq!(td, nd, "d2: seed {seed} n {n} d {d} k {k} threads {threads}");
        }
    }
}

#[test]
fn prop_exact_global_matches_naive_exactly() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(200 + seed);
        let n = 2 + rng.below(150);
        let d = 1 + rng.below(20);
        let k = 1 + rng.below(10);
        let x = int_matrix(&mut rng, n, d, 7);
        assert_eq!(
            exact_global(&x, k),
            exact_global_naive(&x, k),
            "seed {seed} n {n} d {d} k {k}"
        );
    }
}

#[test]
fn prop_within_clusters_matches_naive_exactly() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(300 + seed);
        let n = 5 + rng.below(180);
        let d = 1 + rng.below(16);
        let k = 1 + rng.below(8);
        let n_clusters = 1 + rng.below(9);
        let x = int_matrix(&mut rng, n, d, 6);
        // random partition, including empty clusters and singletons
        let mut clusters: Vec<Vec<u32>> = vec![Vec::new(); n_clusters];
        for i in 0..n as u32 {
            clusters[rng.below(n_clusters)].push(i);
        }
        let tiled = within_clusters(&x, &clusters, k, &NativeBackend::default());
        let naive = within_clusters_naive(&x, &clusters, k);
        assert_eq!(tiled, naive, "seed {seed} n {n} d {d} k {k} clusters {n_clusters}");
    }
}

#[test]
fn prop_tiled_results_invariant_to_thread_count() {
    // continuous data here: thread-count invariance must hold for real
    // float distances, not just the exact-integer regime
    for seed in 0..10u64 {
        let mut rng = Rng::new(400 + seed);
        let n = TILE_Q + 1 + rng.below(3 * TILE_Q);
        let d = 1 + rng.below(48);
        let k = 1 + rng.below(20);
        let x = gauss_matrix(&mut rng, n, d);
        let c = gauss_matrix(&mut rng, 1 + rng.below(2 * TILE_C), d);
        let assign_1 = assign_tiled(&x, &c, 1);
        let knn_1 = self_knn_tiled(&x, k, 1);
        for threads in [2usize, 4] {
            assert_eq!(
                assign_tiled(&x, &c, threads),
                assign_1,
                "assign: seed {seed} threads {threads}"
            );
            assert_eq!(
                self_knn_tiled(&x, k, threads),
                knn_1,
                "knn: seed {seed} threads {threads}"
            );
        }
    }
}

#[test]
fn prop_tiled_distances_accurate_on_gaussian_data() {
    // norm-trick rounding vs the pointwise formula stays tiny relative to
    // unit-scale gaussian data
    for seed in 0..10u64 {
        let mut rng = Rng::new(500 + seed);
        let n = 10 + rng.below(120);
        let d = 2 + rng.below(30);
        let k = 1 + rng.below(6).min(n - 2);
        let x = gauss_matrix(&mut rng, n, d);
        let (idx, dd) = self_knn_tiled(&x, k, 2);
        for i in 0..n {
            for s in 0..k {
                let j = idx[i * k + s];
                if j == u32::MAX {
                    continue;
                }
                let real = nomad::linalg::d2(x.row(i), x.row(j as usize));
                let err = (dd[i * k + s] - real).abs();
                assert!(err < 1e-3, "seed {seed} row {i} slot {s}: err {err}");
            }
        }
    }
}

#[test]
fn nan_rows_do_not_panic_anywhere() {
    let mut rng = Rng::new(600);
    let mut x = gauss_matrix(&mut rng, 30, 5);
    x.data[7] = f32::NAN;
    x.data[60] = f32::NAN;
    let c = gauss_matrix(&mut rng, 4, 5);
    // engine paths
    assert_eq!(assign_tiled(&x, &c, 2).len(), 30);
    assert_eq!(self_knn_tiled(&x, 3, 2).0.len(), 90);
    // naive oracles (the old partial_cmp sorts would panic here)
    assert_eq!(assign_naive(&x, &c).len(), 30);
    assert_eq!(knn_naive(&x, 3).0.len(), 90);
    let be = NativeBackend::default();
    let clusters = vec![(0..30u32).collect::<Vec<_>>()];
    assert_eq!(within_clusters(&x, &clusters, 3, &be).0.len(), 90);
}

#[test]
fn backend_trait_paths_match_engine() {
    // NativeBackend must be a thin veneer over the engine
    let mut rng = Rng::new(700);
    let x = int_matrix(&mut rng, 90, 12, 6);
    let c = int_matrix(&mut rng, 11, 12, 6);
    let be = NativeBackend::default();
    assert_eq!(be.assign(&x, &c), assign_naive(&x, &c));
    assert_eq!(be.knn(&x, 7), knn_naive(&x, 7));
    assert_eq!(be.knn_with_budget(&x, 7, 2), knn_naive(&x, 7));
}
