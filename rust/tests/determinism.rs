//! End-to-end determinism: the distributed simulator must replay
//! bit-identically from a seed, even though every device steps its blocks
//! on multiple worker threads.  This holds because (a) each block's RNG is
//! forked from (device, epoch, block index) rather than shared, (b) the
//! parallel gradient reduces its chunk accumulators in a fixed order, and
//! (c) the leader folds device replies in device order, not arrival order.

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::coordinator::{NomadCoordinator, NomadRun, RunConfig};
use nomad::data::{gaussian_mixture, Dataset};
use nomad::embed::NomadParams;
use nomad::util::rng::Rng;

fn corpus() -> Dataset {
    let mut rng = Rng::new(3);
    gaussian_mixture(600, 16, 4, 10.0, 0.2, 0.5, &mut rng)
}

fn fit_once(ds: &Dataset, seed: u64, n_devices: usize) -> NomadRun {
    let coord = NomadCoordinator::new(
        NomadParams { epochs: 15, k: 5, negs: 4, seed, ..Default::default() },
        RunConfig {
            n_devices,
            index: IndexParams { n_clusters: 4, k: 5, ..Default::default() },
            ..Default::default()
        },
    );
    coord.fit(ds, &NativeBackend::default())
}

#[test]
fn fit_replays_bit_identically_from_a_seed() {
    let ds = corpus();
    let a = fit_once(&ds, 42, 3);
    let b = fit_once(&ds, 42, 3);
    assert_eq!(a.positions.data, b.positions.data, "final positions must be identical");
    assert_eq!(a.loss_history, b.loss_history, "loss history must be identical");
    assert_eq!(a.final_means, b.final_means, "means table must be identical");
}

#[test]
fn single_device_fit_replays_bit_identically() {
    let ds = corpus();
    let a = fit_once(&ds, 7, 1);
    let b = fit_once(&ds, 7, 1);
    assert_eq!(a.positions.data, b.positions.data);
    assert_eq!(a.final_means, b.final_means);
}

#[test]
fn different_seeds_produce_different_embeddings() {
    let ds = corpus();
    let a = fit_once(&ds, 1, 2);
    let b = fit_once(&ds, 2, 2);
    assert_ne!(a.positions.data, b.positions.data);
}
