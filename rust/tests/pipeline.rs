//! End-to-end pipeline invariants — the properties Fig 2 of the paper
//! promises, validated on the full coordinator stack.

use nomad::ann::backend::NativeBackend;
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::coordinator::{NomadCoordinator, RunConfig};
use nomad::data::{gaussian_mixture, wikipedia_like};
use nomad::distributed::sharder::shard_clusters;
use nomad::distributed::MEAN_ENTRY_BYTES;
use nomad::embed::NomadParams;
use nomad::harness::{evaluate, EvalCfg};
use nomad::metrics::random_triplet_accuracy;
use nomad::util::rng::Rng;

/// Fig 2's core claim: the ANN graph's edges never cross cluster (and
/// therefore never cross device) boundaries.
#[test]
fn positive_edges_never_cross_devices() {
    let mut rng = Rng::new(0);
    let ds = wikipedia_like(3000, &mut rng);
    let idx = ClusterIndex::build(
        &ds.x,
        &IndexParams { n_clusters: 24, ..Default::default() },
        &NativeBackend::default(),
        &mut rng,
    );
    assert!(idx.edges_respect_clusters());

    // shard and double check at device granularity
    let sizes: Vec<usize> = idx.clusters.iter().map(|c| c.len()).collect();
    let shards = shard_clusters(&sizes, 4);
    let mut device_of_cluster = vec![usize::MAX; idx.n_clusters()];
    for (d, s) in shards.iter().enumerate() {
        for &c in s {
            device_of_cluster[c] = d;
        }
    }
    for i in 0..idx.n() {
        let di = device_of_cluster[idx.assign[i] as usize];
        for &j in idx.neighbors(i) {
            if j != nomad::ann::NO_NEIGHBOR {
                let dj = device_of_cluster[idx.assign[j as usize] as usize];
                assert_eq!(di, dj, "edge {i}->{j} crosses devices");
            }
        }
    }
}

/// The all-gather volume is exactly |clusters| x 16 bytes x devices x epochs
/// — nothing else crosses the (simulated) wire.
#[test]
fn allgather_volume_is_exactly_the_means_table() {
    let mut rng = Rng::new(1);
    let ds = gaussian_mixture(900, 16, 6, 10.0, 0.2, 0.5, &mut rng);
    let devices = 3;
    let epochs = 7;
    let coord = NomadCoordinator::new(
        NomadParams { epochs, ..Default::default() },
        RunConfig {
            n_devices: devices,
            index: IndexParams { n_clusters: 6, ..Default::default() },
            ..Default::default()
        },
    );
    let run = coord.fit(&ds, &NativeBackend::default());
    let expect =
        run.n_clusters as u64 * MEAN_ENTRY_BYTES * devices as u64 * epochs as u64;
    assert_eq!(run.comm.allgather_bytes_total, expect);
    assert_eq!(run.comm.positive_phase_bytes_total, 0);
}

/// Same seed, same config -> bit-identical positions (native backend):
/// whole-run determinism across index build, sharding, and SGD.
#[test]
fn runs_are_deterministic() {
    let mut rng = Rng::new(2);
    let ds = gaussian_mixture(500, 8, 4, 10.0, 0.2, 0.5, &mut rng);
    let fit = || {
        let coord = NomadCoordinator::new(
            NomadParams { epochs: 15, seed: 5, ..Default::default() },
            RunConfig {
                n_devices: 2,
                index: IndexParams { n_clusters: 4, ..Default::default() },
                ..Default::default()
            },
        );
        coord.fit(&ds, &NativeBackend::default())
    };
    let a = fit();
    let b = fit();
    assert_eq!(a.positions.data, b.positions.data);
    assert_eq!(a.loss_history, b.loss_history);
}

/// Training must substantially beat a random projection on both metrics.
#[test]
fn quality_beats_random_projection() {
    let mut rng = Rng::new(3);
    let ds = gaussian_mixture(1200, 32, 8, 12.0, 0.3, 0.6, &mut rng);
    let coord = NomadCoordinator::new(
        NomadParams { epochs: 80, ..Default::default() },
        RunConfig {
            n_devices: 2,
            index: IndexParams { n_clusters: 12, ..Default::default() },
            ..Default::default()
        },
    );
    let run = coord.fit(&ds, &NativeBackend::default());
    let cfg = EvalCfg { np_sample: 250, triplets: 6000, ..Default::default() };
    let (np, rta) = evaluate(&ds, &run.positions, &cfg);

    let mut random = nomad::linalg::Matrix::zeros(ds.n(), 2);
    for v in random.data.iter_mut() {
        *v = rng.normal();
    }
    let (np_r, rta_r) = evaluate(&ds, &random, &cfg);
    assert!(np > np_r * 3.0 + 0.05, "NP {np} vs random {np_r}");
    assert!(rta > rta_r + 0.1, "RTA {rta} vs random {rta_r}");
}

/// More devices must not degrade local quality catastrophically (paper
/// reports NP parity/improvement with more GPUs; RTA may dip slightly).
#[test]
fn multi_device_preserves_local_quality() {
    let mut rng = Rng::new(4);
    let ds = gaussian_mixture(1000, 16, 8, 10.0, 0.2, 0.5, &mut rng);
    let cfg = EvalCfg { np_sample: 250, triplets: 5000, ..Default::default() };
    let mut nps = Vec::new();
    for devices in [1usize, 4] {
        let coord = NomadCoordinator::new(
            NomadParams { epochs: 60, ..Default::default() },
            RunConfig {
                n_devices: devices,
                index: IndexParams { n_clusters: 8, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        let (np, _) = evaluate(&ds, &run.positions, &cfg);
        nps.push(np);
    }
    assert!(
        nps[1] > nps[0] * 0.7,
        "4-device NP {} vs 1-device {}",
        nps[1],
        nps[0]
    );
}

/// PCA init should give global structure (RTA) at least on par with random
/// init, matching §3.4's motivation.
#[test]
fn pca_init_improves_global_structure() {
    let mut rng = Rng::new(5);
    let ds = gaussian_mixture(900, 32, 6, 14.0, 0.2, 0.4, &mut rng);
    let mut rtas = Vec::new();
    for pca in [true, false] {
        let coord = NomadCoordinator::new(
            NomadParams { epochs: 40, pca_init: pca, ..Default::default() },
            RunConfig {
                n_devices: 2,
                index: IndexParams { n_clusters: 8, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        let mut mrng = Rng::new(11);
        rtas.push(random_triplet_accuracy(&ds.x, &run.positions, 6000, &mut mrng));
    }
    assert!(
        rtas[0] > rtas[1] - 0.02,
        "PCA RTA {} should not trail random {}",
        rtas[0],
        rtas[1]
    );
}
