//! Miri soundness smoke tests (DESIGN.md §14).
//!
//! Every unsafe block in the tree lives in the parallel dispatch
//! primitives, the mmap wrapper, or the gather/knn engines built on top of
//! them.  This suite drives each of those through *small* shapes so the
//! whole file stays tractable under Miri (~1000x slowdown) while still
//! exercising the aliasing-sensitive paths: disjoint-slot writes in
//! `par_map`/`par_map_mut`, disjoint row slices in `par_rows_mut`, the
//! owner-computes gather engine, wire-frame decoding, and the `Mmap`
//! fallback (an owned Vec under Miri, same `ptr`/`len` slice
//! reconstruction as the real mapping).
//!
//! CI runs `cargo +nightly miri test --test miri_smoke` with
//! `MIRIFLAGS=-Zmiri-disable-isolation` (the mmap and shard tests touch
//! the filesystem).  The same tests pass natively, so the file also runs
//! in the plain tier-1 sweep.

use nomad::embed::native::{nomad_grad_gather, nomad_grad_serial};
use nomad::embed::EdgeTranspose;
use nomad::util::parallel::{par_for_chunks, par_map, par_map_mut, par_rows_mut};
use nomad::util::rng::Rng;

#[test]
fn par_map_small_shapes() {
    for (n, threads) in [(0usize, 4usize), (1, 4), (7, 3), (16, 4)] {
        let out = par_map(n, threads, |i| i * i);
        assert_eq!(out, (0..n).map(|i| i * i).collect::<Vec<_>>());
    }
}

#[test]
fn par_map_mut_small_shapes() {
    for (n, threads) in [(1usize, 4usize), (5, 2), (12, 4)] {
        let mut items: Vec<u64> = (0..n as u64).collect();
        let out = par_map_mut(&mut items, threads, |i, v| {
            *v += 100;
            i as u64
        });
        assert_eq!(items, (100..100 + n as u64).collect::<Vec<_>>());
        assert_eq!(out, (0..n as u64).collect::<Vec<_>>());
    }
}

#[test]
fn par_for_chunks_small_shapes() {
    use std::sync::atomic::{AtomicU8, Ordering};
    let n = 13;
    let hits: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(0)).collect();
    par_for_chunks(n, 3, 4, |a, b| {
        for h in &hits[a..b] {
            h.fetch_add(1, Ordering::Relaxed);
        }
    });
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
}

#[test]
fn par_rows_mut_small_shapes() {
    let cols = 3;
    let rows = 7;
    let mut m = vec![0f32; rows * cols];
    par_rows_mut(&mut m, cols, 2, 4, |r0, chunk| {
        for (dr, row) in chunk.chunks_mut(cols).enumerate() {
            for v in row.iter_mut() {
                *v = (r0 + dr) as f32;
            }
        }
    });
    for r in 0..rows {
        for c in 0..cols {
            assert_eq!(m[r * cols + c], r as f32);
        }
    }
}

#[test]
fn gather_engine_tiny_vs_serial_oracle() {
    // one tiny padded problem through the unsafe gather path, 2 workers
    let mut rng = Rng::new(42);
    let (size, n_real, k, negs, r) = (8usize, 6usize, 2usize, 2usize, 3usize);
    let pos: Vec<f32> = (0..size * 2).map(|_| rng.normal()).collect();
    let mut nbr_idx = vec![0i32; size * k];
    let mut nbr_w = vec![0.0f32; size * k];
    let mut neg_idx = vec![0i32; size * negs];
    for i in 0..size {
        for s in 0..k {
            nbr_idx[i * k + s] = rng.below(n_real) as i32;
            nbr_w[i * k + s] = if i < n_real { rng.f32() } else { 0.0 };
        }
        for s in 0..negs {
            neg_idx[i * negs + s] = if i < n_real { rng.below(n_real) as i32 } else { i as i32 };
        }
    }
    let neg_w = 0.5f32;
    let means: Vec<f32> = (0..r * 2).map(|_| rng.normal()).collect();
    let mean_w: Vec<f32> = (0..r).map(|_| rng.f32()).collect();
    let mut valid = vec![0.0f32; size];
    for v in valid.iter_mut().take(n_real) {
        *v = 1.0;
    }
    let nbr_in = EdgeTranspose::build(&nbr_idx, size, k, |e| nbr_w[e] != 0.0);
    let neg_in = EdgeTranspose::build(&neg_idx, size, negs, |_| true);
    let mx: Vec<f32> = means.iter().step_by(2).copied().collect();
    let my: Vec<f32> = means.iter().skip(1).step_by(2).copied().collect();

    let (gs, ls) = nomad_grad_serial(
        &pos, &nbr_idx, &nbr_w, &neg_idx, neg_w, &means, &mean_w, &valid, k, negs,
    );
    let (gg, lg) = nomad_grad_gather(
        &pos, &nbr_idx, &nbr_w, &nbr_in, &neg_idx, &neg_in, neg_w, &mx, &my, &mean_w, &valid, k,
        negs, 2,
    );
    assert!((ls - lg).abs() < 1e-5 * (1.0 + ls.abs()), "loss serial {ls} vs gather {lg}");
    for i in 0..size * 2 {
        assert!(gg[i].is_finite(), "coord {i} not finite");
        assert!(
            (gs[i] - gg[i]).abs() < 1e-5 * (1.0 + gs[i].abs()),
            "coord {i}: serial {} gather {}",
            gs[i],
            gg[i]
        );
    }
    for l in n_real..size {
        assert_eq!(gg[l * 2], 0.0, "padding row {l} moved");
        assert_eq!(gg[l * 2 + 1], 0.0, "padding row {l} moved");
    }
}

#[test]
fn proto_roundtrip_and_corruption() {
    use nomad::distributed::proto::{decode, encode, Role, WireMsg};
    for msg in [WireMsg::Hello { role: Role::Coordinator }, WireMsg::Hello { role: Role::Worker }] {
        let frame = encode(&msg);
        let back = decode(&frame).expect("round-trip decode");
        assert_eq!(msg, back);
        // a flipped payload bit must be an Err, never a panic
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        assert!(decode(&bad).is_err());
        // truncation at every prefix must be an Err, never a panic
        for cut in 0..frame.len() {
            assert!(decode(&frame[..cut]).is_err(), "prefix {cut} must fail");
        }
    }
}

#[test]
fn mmap_fallback_roundtrip() {
    // under Miri the Vec-backed fallback path is taken; natively this is
    // the real mmap. Both reconstruct the slice from a raw ptr/len pair.
    let dir = std::env::temp_dir().join("nomad_miri_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("m.bin");
    let data: Vec<u8> = (0..=127u8).collect();
    std::fs::write(&p, &data).unwrap();
    let m = nomad::util::mmap::Mmap::open(&p).unwrap();
    assert_eq!(m.bytes(), &data[..]);
    let shared = std::sync::Arc::new(m);
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let m = std::sync::Arc::clone(&shared);
            std::thread::spawn(move || m.bytes().iter().map(|&b| b as u32).sum::<u32>())
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), (0..=127u32).sum::<u32>());
    }
}
