//! Integration-level property tests of the wire protocol (DESIGN.md §12):
//! every `DeviceCmd`/`DeviceReply` variant round-trips through the public
//! encode/decode API, and malformed frames — truncated, bit-flipped,
//! wrong-version, alien — come back as errors, never panics.

use nomad::distributed::device::{DeviceCmd, DeviceReply};
use nomad::distributed::proto::{
    decode, encode, frame_len, read_frame, write_frame, Assignment, Role, WireMsg, HEADER_BYTES,
    PROTO_VERSION,
};
use nomad::distributed::MeanEntry;
use nomad::util::rng::Rng;
use std::sync::Arc;

/// One message of every wire variant, with payloads seeded from `rng` so
/// repeated sweeps cover different byte patterns.
fn sample_msgs(rng: &mut Rng) -> Vec<WireMsg> {
    let means: Vec<MeanEntry> = (0..5)
        .map(|i| MeanEntry {
            cluster_id: i,
            mean: [rng.f32() * 10.0 - 5.0, rng.f32() * 10.0 - 5.0],
            weight: rng.f32(),
        })
        .collect();
    let positions: Vec<(u32, [f32; 2])> =
        (0..7).map(|i| (i * 3, [rng.f32(), -rng.f32()])).collect();
    let table: Vec<f32> = (0..16).map(|_| rng.f32() * 2.0 - 1.0).collect();
    vec![
        WireMsg::Hello { role: Role::Coordinator },
        WireMsg::Hello { role: Role::Worker },
        WireMsg::Assign(Assignment {
            device: rng.below(8),
            n_active: 4,
            n_total: 10_000,
            negs: 8,
            seed: rng.next_u64(),
            m_noise: 5.5,
            clusters: (0..6).map(|_| rng.below(64) as u32).collect(),
        }),
        WireMsg::Assigned { device: 3, n_blocks: 6, n_points: 1234 },
        WireMsg::Cmd(DeviceCmd::Epoch {
            epoch: rng.below(500),
            lr: rng.f32() * 100.0,
            exaggeration: 4.0,
            means: Arc::new(means),
        }),
        WireMsg::Cmd(DeviceCmd::Export),
        WireMsg::Cmd(DeviceCmd::Ingest { positions: Arc::new(table) }),
        WireMsg::Cmd(DeviceCmd::Stop),
        WireMsg::Reply(DeviceReply::EpochDone {
            device: 1,
            means: vec![MeanEntry { cluster_id: 9, mean: [1.5, -2.5], weight: 0.25 }],
            loss_sum: -123.456,
            loss_weight: 789.0,
            step_secs: 0.0625,
            flops: 1.5e9,
        }),
        WireMsg::Reply(DeviceReply::Exported { device: 2, positions }),
        WireMsg::Reply(DeviceReply::Ingested { device: 7 }),
    ]
}

#[test]
fn every_variant_roundtrips_across_many_seeds() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        for msg in sample_msgs(&mut rng) {
            let frame = encode(&msg);
            assert_eq!(frame.len(), frame_len(&msg), "frame_len must predict {msg:?}");
            let back = decode(&frame).expect("well-formed frame decodes");
            assert_eq!(back, msg);
        }
    }
}

#[test]
fn truncation_at_every_boundary_is_an_error() {
    let mut rng = Rng::new(1);
    for msg in sample_msgs(&mut rng) {
        let frame = encode(&msg);
        for cut in 0..frame.len() {
            let mut r = std::io::Cursor::new(&frame[..cut]);
            assert!(
                read_frame(&mut r).is_err(),
                "a frame cut to {cut}/{} bytes must not decode ({msg:?})",
                frame.len()
            );
        }
    }
}

#[test]
fn every_single_bit_flip_is_rejected() {
    // every header bit is either checked by value (magic, version) or
    // covered by the frame crc (type, length, payload), so no flip
    // anywhere in a frame may decode — not even to the same message
    let mut rng = Rng::new(2);
    for msg in sample_msgs(&mut rng) {
        let frame = encode(&msg);
        for byte in 0..frame.len() {
            for bit in 0..8 {
                let mut bad = frame.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    decode(&bad).is_err(),
                    "flip at byte {byte} bit {bit} of {msg:?} still decoded"
                );
            }
        }
    }
}

#[test]
fn wrong_version_is_rejected_with_both_versions_named() {
    let msg = WireMsg::Hello { role: Role::Worker };
    let mut frame = encode(&msg);
    let bumped = PROTO_VERSION + 1;
    frame[4..6].copy_from_slice(&bumped.to_le_bytes());
    let e = decode(&frame).unwrap_err().to_string();
    assert!(
        e.contains(&PROTO_VERSION.to_string()) && e.contains(&bumped.to_string()),
        "version error should name both versions: {e}"
    );
}

#[test]
fn streams_of_frames_read_back_in_order() {
    let mut rng = Rng::new(3);
    let msgs = sample_msgs(&mut rng);
    let mut buf = Vec::new();
    let mut want_bytes = 0usize;
    for m in &msgs {
        want_bytes += write_frame(&mut buf, m).expect("write frame");
    }
    assert_eq!(buf.len(), want_bytes);
    let mut r = std::io::Cursor::new(&buf[..]);
    for m in &msgs {
        let (got, n) = read_frame(&mut r).expect("read frame");
        assert_eq!(&got, m);
        assert!(n >= HEADER_BYTES);
    }
    assert!(read_frame(&mut r).is_err(), "exhausted stream errors cleanly");
}

#[test]
fn fuzz_smoke_over_several_seeds() {
    // the structure-aware fuzzer (distributed::fuzz) must complete with
    // both outcomes represented and identical tallies on replay — any
    // decoder panic fails this test with a two-integer reproducer
    for seed in [0u64, 7, 0xF00D] {
        let a = nomad::distributed::fuzz::run(seed, 250);
        let b = nomad::distributed::fuzz::run(seed, 250);
        assert_eq!(a, b, "fuzz run not deterministic for seed {seed}");
        assert!(a.decoded_ok > 0 && a.rejected > 0, "seed {seed}: degenerate run {a:?}");
    }
}

// ---- regression tests promoted from fuzzing the streaming decoder ----

#[test]
fn hostile_length_claim_does_not_allocate_or_hang() {
    // a header claiming MAX_PAYLOAD with only a few real payload bytes:
    // the reader must grow with the bytes actually received (bounded by
    // EOF), then report a mid-frame close — not reserve 1 GiB up front
    let mut frame = encode(&WireMsg::Cmd(DeviceCmd::Stop));
    frame[8..12].copy_from_slice(&(1u32 << 30).to_le_bytes());
    frame.extend_from_slice(&[0xAB; 32]);
    let mut r = std::io::Cursor::new(&frame[..]);
    let e = read_frame(&mut r).unwrap_err().to_string();
    assert!(e.contains("closed mid-frame"), "wrong failure mode: {e}");
}

#[test]
fn one_byte_at_a_time_delivery_decodes_cleanly() {
    // the pathological fragmentation case the fuzzer's chunked reader
    // approaches: every read returns one byte
    struct OneByte<'a>(&'a [u8]);
    impl std::io::Read for OneByte<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            match (self.0.split_first(), buf.is_empty()) {
                (Some((&b, rest)), false) => {
                    buf[0] = b;
                    self.0 = rest;
                    Ok(1)
                }
                _ => Ok(0),
            }
        }
    }
    let msg = WireMsg::Cmd(DeviceCmd::Ingest { positions: Arc::new(vec![1.0, -2.0, 3.5]) });
    let frame = encode(&msg);
    let (back, n) = read_frame(&mut OneByte(&frame)).expect("fragmented frame decodes");
    assert_eq!(back, msg);
    assert_eq!(n, frame.len());
}

#[test]
fn io_failures_read_as_classified_fault_text() {
    use nomad::distributed::fault::FaultKind;
    // an exhausted stream mid-header must classify as a disconnect, and a
    // timeout errno must classify as a timeout — the recovery supervisor
    // keys off these phrases
    let frame = encode(&WireMsg::Cmd(DeviceCmd::Export));
    let mut r = std::io::Cursor::new(&frame[..HEADER_BYTES - 2]);
    let err = read_frame(&mut r).unwrap_err();
    assert_eq!(FaultKind::classify(&err), FaultKind::Disconnect, "{err}");

    struct TimesOut;
    impl std::io::Read for TimesOut {
        fn read(&mut self, _: &mut [u8]) -> std::io::Result<usize> {
            Err(std::io::Error::from(std::io::ErrorKind::WouldBlock))
        }
    }
    let err = read_frame(&mut TimesOut).unwrap_err();
    assert_eq!(FaultKind::classify(&err), FaultKind::Timeout, "{err}");
}

#[test]
fn special_floats_survive_the_wire_bitwise() {
    let weird = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1e-42];
    let msg = WireMsg::Cmd(DeviceCmd::Ingest {
        positions: Arc::new(weird.iter().copied().chain(weird.iter().copied()).collect()),
    });
    let back = decode(&encode(&msg)).unwrap();
    match back {
        WireMsg::Cmd(DeviceCmd::Ingest { positions }) => {
            for (a, b) in weird.iter().chain(weird.iter()).zip(positions.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        other => panic!("wrong variant back: {other:?}"),
    }
}
