//! Remote-placement end-to-end tests: a coordinator driving worker
//! sessions over real sockets (TCP loopback and unix) must produce
//! **bitwise identical** final positions to the in-process thread
//! placement with the same seeds — the tentpole invariant of
//! DESIGN.md §12.  Workers here run on threads; CI's worker-smoke job
//! repeats the TCP case with real `nomad worker` OS processes.

use nomad::ann::backend::NativeBackend;
use nomad::ann::graph::edge_weights;
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::checkpoint::DatasetSpec;
use nomad::coordinator::{NomadCoordinator, NomadRun, Placement, RunConfig};
use nomad::data::shard::write_shards;
use nomad::data::{text_corpus_like, Dataset};
use nomad::distributed::transport::Endpoint;
use nomad::distributed::worker::{run_worker, WorkerCfg};
use nomad::embed::NomadParams;
use nomad::util::rng::Rng;
use std::path::PathBuf;

const SEED: u64 = 7;
const N: usize = 600;
const EPOCHS: usize = 4;
const CLUSTERS: usize = 8;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nomad_mp_{tag}_{}", std::process::id()))
}

fn dataset() -> Dataset {
    let mut rng = Rng::new(0);
    text_corpus_like(N, &mut rng)
}

fn coordinator(placement: Placement, n_devices: usize, seed: u64) -> NomadCoordinator {
    NomadCoordinator::new(
        NomadParams { epochs: EPOCHS, seed, ..Default::default() },
        RunConfig {
            n_devices,
            index: IndexParams { n_clusters: CLUSTERS, ..Default::default() },
            placement,
            ..Default::default()
        },
    )
}

/// Write the shard set `nomad shard` would write for this dataset/seed —
/// the same `Rng::new(seed)` stream prefix `prepare()` uses, so the
/// topology matches the coordinator's index exactly.
fn write_shard_set(dir: &PathBuf, ds: &Dataset, seed: u64) {
    let _ = std::fs::remove_dir_all(dir);
    let idxp = IndexParams { n_clusters: CLUSTERS, ..Default::default() };
    let mut rng = Rng::new(seed);
    let index = ClusterIndex::build(&ds.x, &idxp, &NativeBackend::default(), &mut rng);
    let weights = edge_weights(&index, NomadParams::default().weight_model);
    let spec = DatasetSpec { kind: "synthetic".into(), source: "arxiv".into(), n: N, seed: 0 };
    let model = NomadParams::default().weight_model;
    write_shards(dir, &index, &weights, ds.dim(), seed, model, &idxp, &spec)
        .expect("write shard set");
}

/// Host one full worker lifecycle (bind, accept, serve, exit) per endpoint
/// on a thread — exactly the code path `nomad worker` runs in a process.
fn spawn_workers(
    shard_dir: &PathBuf,
    endpoints: Vec<Endpoint>,
) -> (Vec<String>, Vec<std::thread::JoinHandle<()>>) {
    let mut specs = Vec::new();
    let mut joins = Vec::new();
    for ep in endpoints {
        specs.push(match &ep {
            Endpoint::Tcp(addr) => addr.clone(),
            #[cfg(unix)]
            Endpoint::Unix(p) => format!("unix:{}", p.display()),
        });
        let dir = shard_dir.clone();
        joins.push(std::thread::spawn(move || {
            run_worker(&ep, &dir, &WorkerCfg::default()).expect("worker run");
        }));
    }
    (specs, joins)
}

fn in_process_reference(ds: &Dataset) -> NomadRun {
    let coord = coordinator(Placement::InProcess, 2, SEED);
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    coord.fit_resumable(N, &prep, None).expect("in-process run")
}

fn assert_bitwise_equal(a: &NomadRun, b: &NomadRun) {
    assert_eq!(a.positions.data.len(), b.positions.data.len());
    for (i, (x, y)) in a.positions.data.iter().zip(&b.positions.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "positions diverge at f32 #{i}: {x} vs {y}"
        );
    }
    assert_eq!(a.final_means.len(), b.final_means.len());
    for (ea, eb) in a.final_means.iter().zip(&b.final_means) {
        assert_eq!(ea.cluster_id, eb.cluster_id);
        assert_eq!(ea.mean[0].to_bits(), eb.mean[0].to_bits());
        assert_eq!(ea.mean[1].to_bits(), eb.mean[1].to_bits());
        assert_eq!(ea.weight.to_bits(), eb.weight.to_bits());
    }
}

#[test]
fn tcp_workers_match_in_process_bitwise() {
    let ds = dataset();
    let shard_dir = scratch("tcp");
    write_shard_set(&shard_dir, &ds, SEED);

    // `:0` binds race-free ephemeral ports, but run_worker binds inside
    // the worker thread — so bind fixed ports picked by the OS up front
    let ports: Vec<u16> = (0..2)
        .map(|_| {
            let l = std::net::TcpListener::bind("127.0.0.1:0").expect("probe port");
            let p = l.local_addr().expect("probe addr").port();
            drop(l);
            p
        })
        .collect();
    let eps: Vec<Endpoint> =
        ports.iter().map(|p| Endpoint::Tcp(format!("127.0.0.1:{p}"))).collect();
    let (endpoints, joins) = spawn_workers(&shard_dir, eps);

    let coord = coordinator(
        Placement::Remote { endpoints, shards: shard_dir.clone() },
        2,
        SEED,
    );
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let remote = coord.fit_resumable(N, &prep, None).expect("remote run");
    for j in joins {
        j.join().expect("worker thread");
    }

    let reference = in_process_reference(&ds);
    assert_bitwise_equal(&reference, &remote);
    assert!(remote.comm.wire_bytes_total > 0, "remote run must account real wire bytes");
    assert_eq!(
        remote.comm.wire_epoch_bytes.len(),
        EPOCHS,
        "one measured wire-byte sample per epoch"
    );
    let _ = std::fs::remove_dir_all(&shard_dir);
}

#[cfg(unix)]
#[test]
fn unix_socket_workers_match_in_process_bitwise() {
    let ds = dataset();
    let shard_dir = scratch("unix");
    write_shard_set(&shard_dir, &ds, SEED);

    let eps: Vec<Endpoint> = (0..2)
        .map(|i| Endpoint::Unix(std::env::temp_dir().join(format!(
            "nomad_mp_sock_{}_{i}",
            std::process::id()
        ))))
        .collect();
    let (endpoints, joins) = spawn_workers(&shard_dir, eps);

    let coord = coordinator(
        Placement::Remote { endpoints, shards: shard_dir.clone() },
        2,
        SEED,
    );
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let remote = coord.fit_resumable(N, &prep, None).expect("remote run");
    for j in joins {
        j.join().expect("worker thread");
    }

    let reference = in_process_reference(&ds);
    assert_bitwise_equal(&reference, &remote);
    let _ = std::fs::remove_dir_all(&shard_dir);
}

#[test]
fn mismatched_shard_set_is_refused_before_connecting() {
    let ds = dataset();
    let shard_dir = scratch("mismatch");
    // shard set built from a different seed: topology cannot match
    write_shard_set(&shard_dir, &ds, SEED ^ 1);

    // endpoints are never dialed — manifest validation must fail first
    let coord = coordinator(
        Placement::Remote {
            endpoints: vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            shards: shard_dir.clone(),
        },
        2,
        SEED,
    );
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let err = coord
        .fit_resumable(N, &prep, None)
        .expect_err("a foreign shard set must be refused");
    assert!(err.to_string().contains("seed"), "error should name the mismatch: {err}");
    let _ = std::fs::remove_dir_all(&shard_dir);
}

#[test]
fn missing_shard_dir_is_a_clean_error() {
    let ds = dataset();
    let coord = coordinator(
        Placement::Remote {
            endpoints: vec!["127.0.0.1:1".into(), "127.0.0.1:1".into()],
            shards: scratch("nonexistent"),
        },
        2,
        SEED,
    );
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    assert!(coord.fit_resumable(N, &prep, None).is_err());
}
