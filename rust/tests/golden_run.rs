//! Golden-run regression fixture: a tiny seeded run's final state digest
//! (crc32 of the canonical little-endian byte encoding of positions +
//! loss history), computed at 1 and 4 worker threads.
//!
//! Two layers of protection against silent numeric drift:
//!
//! 1. **thread invariance** (always enforced): the digest must be
//!    identical at 1 and 4 threads — the gather engine / device runtime
//!    bitwise-determinism contract (DESIGN.md §7/§9);
//! 2. **cross-version pin**: the digest is compared against
//!    `tests/golden/run_digest.txt`.  The first run on a machine writes
//!    the fixture (bless mode); once the file is **committed**, any
//!    future engine change that shifts a single bit of the final
//!    positions or loss history fails this test loudly.  To re-bless
//!    after an *intentional* numeric change, delete the fixture and
//!    re-run.  NOTE: until the fixture is committed, a fresh checkout
//!    only enforces layer 1 — run the test once and commit the
//!    generated file to arm the cross-version pin.
//!
//! NOTE: this file must stay a single `#[test]` — it mutates the
//! process-wide `NOMAD_THREADS` env var, and tests within one binary run
//! concurrently.

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::coordinator::{NomadCoordinator, NomadRun, RunConfig};
use nomad::data::gaussian_mixture;
use nomad::embed::NomadParams;
use nomad::util::rng::Rng;
use nomad::viz::png::crc32;
use std::path::PathBuf;

/// Canonical byte encoding: positions (f32 LE, row-major) then the loss
/// history (f64 LE).  Any bit of drift in either changes the crc.
fn digest(run: &NomadRun) -> u32 {
    let mut bytes =
        Vec::with_capacity(run.positions.data.len() * 4 + run.loss_history.len() * 8);
    for v in &run.positions.data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    for l in &run.loss_history {
        bytes.extend_from_slice(&l.to_le_bytes());
    }
    crc32(&bytes)
}

fn golden_fit() -> NomadRun {
    let mut rng = Rng::new(5);
    let ds = gaussian_mixture(360, 12, 3, 9.0, 0.15, 0.4, &mut rng);
    let coord = NomadCoordinator::new(
        NomadParams { epochs: 12, k: 5, negs: 4, seed: 1234, ..Default::default() },
        RunConfig {
            n_devices: 2,
            index: IndexParams { n_clusters: 3, k: 5, ..Default::default() },
            ..Default::default()
        },
    );
    coord.fit(&ds, &NativeBackend::default())
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/run_digest.txt")
}

#[test]
fn golden_run_digest_is_thread_invariant_and_pinned() {
    let mut digests = Vec::new();
    for threads in [1usize, 4] {
        std::env::set_var("NOMAD_THREADS", threads.to_string());
        digests.push((threads, digest(&golden_fit())));
    }
    std::env::remove_var("NOMAD_THREADS");
    assert_eq!(
        digests[0].1, digests[1].1,
        "golden digest differs across thread counts ({:08x} @1t vs {:08x} @4t) — \
         the bitwise thread-invariance contract is broken",
        digests[0].1, digests[1].1
    );

    let got = format!("{:08x}", digests[0].1);
    let path = fixture_path();
    match std::fs::read_to_string(&path) {
        Ok(pinned) => {
            assert_eq!(
                pinned.trim(),
                got,
                "golden run digest drifted from the pinned fixture {} — an engine \
                 change moved the final positions/loss bits; if intentional, delete \
                 the fixture and re-run to re-bless",
                path.display()
            );
        }
        Err(_) => {
            // bless mode: first run pins the digest; commit the file
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, format!("{got}\n")).unwrap();
            eprintln!("[golden_run] pinned new fixture {} = {got}", path.display());
        }
    }
}
