//! Chaos matrix for the fault-tolerant distributed runtime (DESIGN.md
//! §13): every fault class (corrupt / hang / disconnect / silent drop),
//! injected at every protocol phase (handshake, assignment, ingest, epoch
//! compute, export), on either side of the wire, must end one of exactly
//! two ways —
//!
//!   * the coordinator classifies the fault, rolls back to the newest
//!     valid checkpoint (or the run's start), re-places the lost device
//!     (rotating onto a surviving endpoint when its worker died), and the
//!     finished run is **bitwise identical** to the in-process reference;
//!   * or it fails fast with a classified error.
//!
//! Never an unbounded wait: every scenario's coordinator runs under a
//! watchdog thread that panics if it wedges.  Faults are scripted through
//! `WorkerCfg::faults` (worker side, per accepted session) and
//! `RecoveryCfg::fault_plans` (coordinator side, first establishment
//! only), so every scenario replays deterministically.

use nomad::ann::backend::NativeBackend;
use nomad::ann::graph::edge_weights;
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::checkpoint::{params_fingerprint, run_info_json, DatasetSpec, RunStore};
use nomad::coordinator::{
    CheckpointCfg, NomadCoordinator, NomadRun, Placement, RecoveryCfg, RunConfig,
};
use nomad::data::shard::{write_shards, ShardSet};
use nomad::data::{text_corpus_like, Dataset};
use nomad::distributed::fault::{Dir, FaultAction, FaultKind, FaultPlan};
use nomad::distributed::transport::Endpoint;
use nomad::distributed::worker::{serve_listener, WorkerCfg, WorkerListener};
use nomad::embed::NomadParams;
use nomad::util::error::Error;
use nomad::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::Duration;

const SEED: u64 = 21;
const N: usize = 360;
const EPOCHS: usize = 3;
const CLUSTERS: usize = 6;
const DEVICES: usize = 2;
/// Hard upper bound on any single scenario — "no unbounded waits".
const WATCHDOG: Duration = Duration::from_secs(120);

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nomad_chaos_{tag}_{}", std::process::id()))
}

fn dataset() -> Dataset {
    let mut rng = Rng::new(0);
    text_corpus_like(N, &mut rng)
}

fn coordinator(placement: Placement, rec: RecoveryCfg) -> NomadCoordinator {
    NomadCoordinator::new(
        NomadParams { epochs: EPOCHS, seed: SEED, ..Default::default() },
        RunConfig {
            n_devices: DEVICES,
            index: IndexParams { n_clusters: CLUSTERS, ..Default::default() },
            placement,
            recovery: rec,
            ..Default::default()
        },
    )
}

/// Short deadlines everywhere, so injected hangs and drops surface in
/// seconds instead of the production half-minutes.
fn recovery(fault_plans: Vec<FaultPlan>, max_recoveries: usize) -> RecoveryCfg {
    RecoveryCfg {
        io_timeout: Some(Duration::from_secs(1)),
        epoch_base: Duration::from_secs(2),
        epoch_per_block: Duration::from_millis(200),
        connect_patience: Duration::from_millis(400),
        max_recoveries,
        fault_plans,
    }
}

fn worker_cfg(plan: Option<FaultPlan>, max_sessions: Option<usize>) -> WorkerCfg {
    WorkerCfg {
        verbose: false,
        handshake_timeout: Duration::from_secs(2),
        session_timeout: Some(Duration::from_secs(10)),
        max_sessions,
        faults: plan.into_iter().collect(),
    }
}

/// The shard set `nomad shard` would write for this dataset/seed (same
/// `Rng::new(seed)` prefix as `prepare()`, so topologies match).
fn write_shard_set(dir: &Path, ds: &Dataset, seed: u64) {
    let _ = std::fs::remove_dir_all(dir);
    let idxp = IndexParams { n_clusters: CLUSTERS, ..Default::default() };
    let mut rng = Rng::new(seed);
    let index = ClusterIndex::build(&ds.x, &idxp, &NativeBackend::default(), &mut rng);
    let weights = edge_weights(&index, NomadParams::default().weight_model);
    let spec = DatasetSpec { kind: "synthetic".into(), source: "chaos".into(), n: N, seed: 0 };
    let model = NomadParams::default().weight_model;
    write_shards(dir, &index, &weights, ds.dim(), seed, model, &idxp, &spec)
        .expect("write shard set");
}

/// Bind one listener per worker config (kernel-chosen ports — race free)
/// and serve each on a thread.  Faulted and killed workers exit with their
/// own errors by design, so results are deliberately ignored.
fn spawn_chaos_workers(
    shard_dir: &Path,
    cfgs: Vec<WorkerCfg>,
    unix: bool,
    tag: &str,
) -> (Vec<String>, Vec<std::thread::JoinHandle<()>>) {
    let mut endpoints = Vec::new();
    let mut joins = Vec::new();
    for (i, cfg) in cfgs.into_iter().enumerate() {
        let ep = {
            #[cfg(unix)]
            {
                if unix {
                    Endpoint::Unix(std::env::temp_dir().join(format!(
                        "nomad_chaos_{tag}_{i}_{}",
                        std::process::id()
                    )))
                } else {
                    Endpoint::Tcp("127.0.0.1:0".into())
                }
            }
            #[cfg(not(unix))]
            {
                let _ = (unix, i, tag);
                Endpoint::Tcp("127.0.0.1:0".into())
            }
        };
        let listener = WorkerListener::bind(&ep).expect("bind chaos worker");
        endpoints.push(listener.local_addr_string());
        let shards = Arc::new(ShardSet::open(shard_dir).expect("open shard set"));
        joins.push(std::thread::spawn(move || {
            let _ = serve_listener(listener, shards, &cfg);
        }));
    }
    (endpoints, joins)
}

/// Run `f` on its own thread and panic if it outlives the watchdog — the
/// matrix's "no unbounded waits" teeth.
fn with_watchdog<T: Send + 'static>(tag: &str, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    let t = std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(WATCHDOG) {
        Ok(out) => {
            let _ = t.join();
            out
        }
        Err(_) => panic!(
            "{tag}: coordinator exceeded the {}s watchdog — unbounded wait",
            WATCHDOG.as_secs()
        ),
    }
}

/// One full remote scenario: shard set, workers, coordinator under the
/// watchdog, teardown.  `store_dir` switches on per-epoch checkpointing
/// (every 1, retain 2) so recoveries roll back to a real checkpoint.
fn chaos_run(
    tag: &str,
    worker_cfgs: Vec<WorkerCfg>,
    fault_plans: Vec<FaultPlan>,
    max_recoveries: usize,
    unix: bool,
    store_dir: Option<PathBuf>,
) -> Result<NomadRun, Error> {
    let ds = dataset();
    let shard_dir = scratch(&format!("{tag}_shards"));
    write_shard_set(&shard_dir, &ds, SEED);
    let (endpoints, joins) = spawn_chaos_workers(&shard_dir, worker_cfgs, unix, tag);
    let rec = recovery(fault_plans, max_recoveries);
    let placement = Placement::Remote { endpoints, shards: shard_dir.clone() };
    let out = with_watchdog(tag, move || {
        let ds = dataset();
        let coord = coordinator(placement, rec);
        let prep = coord.prepare(&ds.x, &NativeBackend::default());
        match store_dir {
            Some(dir) => {
                let _ = std::fs::remove_dir_all(&dir);
                let fp = params_fingerprint(N, &coord.params, &coord.run.index);
                let spec = DatasetSpec {
                    kind: "synthetic".into(),
                    source: "chaos".into(),
                    n: N,
                    seed: 0,
                };
                let info = run_info_json(N, DEVICES, &coord.params, &coord.run.index, &spec);
                let mut store = RunStore::create(&dir, fp, info).expect("create run store");
                let cfg = CheckpointCfg {
                    every: 1,
                    retain: 2,
                    artifact: false,
                    labels: None,
                    dataset: "chaos".into(),
                };
                coord.fit_resumable(N, &prep, Some((&mut store, &cfg)))
            }
            None => coord.fit_resumable(N, &prep, None),
        }
    });
    for j in joins {
        let _ = j.join();
    }
    let _ = std::fs::remove_dir_all(&shard_dir);
    out
}

fn in_process_reference() -> NomadRun {
    let ds = dataset();
    let coord = coordinator(Placement::InProcess, RecoveryCfg::default());
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    coord.fit_resumable(N, &prep, None).expect("in-process reference run")
}

fn assert_bitwise_equal(tag: &str, a: &NomadRun, b: &NomadRun) {
    assert_eq!(a.positions.data.len(), b.positions.data.len(), "{tag}: position counts");
    for (i, (x, y)) in a.positions.data.iter().zip(&b.positions.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: positions diverge at f32 #{i}: {x} vs {y}");
    }
    assert_eq!(a.final_means.len(), b.final_means.len(), "{tag}: means table sizes");
    for (ea, eb) in a.final_means.iter().zip(&b.final_means) {
        assert_eq!(ea.cluster_id, eb.cluster_id, "{tag}: means table order");
        assert_eq!(ea.mean[0].to_bits(), eb.mean[0].to_bits(), "{tag}: mean x");
        assert_eq!(ea.mean[1].to_bits(), eb.mean[1].to_bits(), "{tag}: mean y");
        assert_eq!(ea.weight.to_bits(), eb.weight.to_bits(), "{tag}: mean weight");
    }
}

/// A recovered run must have seen (and classified) at least one fault and
/// still match the in-process reference bit for bit.
fn assert_recovered_bitwise(tag: &str, run: &NomadRun, reference: &NomadRun) {
    assert!(run.comm.recoveries >= 1, "{tag}: expected at least one recovery");
    assert!(!run.comm.faults.is_empty(), "{tag}: a recovery must record its faults");
    for f in &run.comm.faults {
        assert_ne!(f.kind, FaultKind::Other, "{tag}: fault must classify, got {f:?}");
    }
    assert_bitwise_equal(tag, reference, run);
}

// ---- recoverable faults, swept across every protocol phase --------------

#[test]
fn corrupted_worker_replies_recover_bitwise_at_every_phase() {
    // worker send frames: 0 Hello, 1 Assigned, 2 Ingested, 3 EpochDone(e0),
    // 4 EpochDone(e1) — the coordinator's crc check detects each in place
    let reference = in_process_reference();
    for frame in 0..=4u64 {
        let tag = format!("send_corrupt_f{frame}");
        let plan = FaultPlan::one(Dir::Send, frame, FaultAction::Corrupt);
        let run = chaos_run(
            &tag,
            vec![worker_cfg(Some(plan), None), worker_cfg(None, None)],
            vec![],
            3,
            false,
            None,
        )
        .unwrap_or_else(|e| panic!("{tag}: must recover, got: {e}"));
        assert_recovered_bitwise(&tag, &run, &reference);
    }
}

#[test]
fn worker_side_receive_corruption_recovers_bitwise_at_every_phase() {
    // worker recv frames: 0 Hello, 1 Assign, 2 Ingest, 3 Epoch(e0),
    // 4 Epoch(e1).  The *worker* sees the crc mismatch and dies; the
    // coordinator observes the hangup — the other detection path
    let reference = in_process_reference();
    for frame in 0..=4u64 {
        let tag = format!("recv_corrupt_f{frame}");
        let plan = FaultPlan::one(Dir::Recv, frame, FaultAction::Corrupt);
        let run = chaos_run(
            &tag,
            vec![worker_cfg(Some(plan), None), worker_cfg(None, None)],
            vec![],
            3,
            false,
            None,
        )
        .unwrap_or_else(|e| panic!("{tag}: must recover, got: {e}"));
        assert_recovered_bitwise(&tag, &run, &reference);
    }
}

#[test]
fn killed_worker_rotates_its_device_to_a_survivor_at_every_phase() {
    // worker 0 dies mid-session (injected disconnect) and its listener is
    // gone (max_sessions = 1): recovery must re-place logical device 0 on
    // the surviving worker, which then hosts both devices' sessions
    let reference = in_process_reference();
    for frame in 0..=4u64 {
        let tag = format!("kill_f{frame}");
        let plan = FaultPlan::one(Dir::Send, frame, FaultAction::Disconnect);
        let run = chaos_run(
            &tag,
            vec![worker_cfg(Some(plan), Some(1)), worker_cfg(None, None)],
            vec![],
            3,
            false,
            None,
        )
        .unwrap_or_else(|e| panic!("{tag}: must rotate and recover, got: {e}"));
        assert_recovered_bitwise(&tag, &run, &reference);
    }
}

#[test]
fn hung_worker_is_cut_off_by_the_deadline_and_recovers() {
    let reference = in_process_reference();
    let plan = FaultPlan::one(Dir::Send, 3, FaultAction::Hang(Duration::from_secs(3)));
    let run = chaos_run(
        "worker_hang",
        vec![worker_cfg(Some(plan), None), worker_cfg(None, None)],
        vec![],
        3,
        false,
        None,
    )
    .expect("a hang must be bounded by the deadline, then recovered");
    assert!(
        run.comm.faults.iter().any(|f| f.kind == FaultKind::Timeout),
        "a hang must classify as a timeout: {:?}",
        run.comm.faults
    );
    assert_recovered_bitwise("worker_hang", &run, &reference);
}

#[test]
fn a_silently_dropped_reply_trips_the_deadline_and_recovers() {
    // device 0's Ingested ack (coordinator recv frame 2) vanishes: the only
    // detector for a silent drop is the deadline, which must fire and
    // classify it as a timeout
    let reference = in_process_reference();
    let plan = FaultPlan::one(Dir::Recv, 2, FaultAction::Drop);
    let run = chaos_run(
        "coord_drop",
        vec![worker_cfg(None, None), worker_cfg(None, None)],
        vec![plan],
        3,
        false,
        None,
    )
    .expect("a dropped frame must be caught by the deadline, then recovered");
    assert!(
        run.comm.faults.iter().any(|f| f.kind == FaultKind::Timeout),
        "a silent drop must surface as a timeout: {:?}",
        run.comm.faults
    );
    assert_recovered_bitwise("coord_drop", &run, &reference);
}

#[test]
fn seeded_coordinator_side_faults_recover_bitwise() {
    // randomized-but-reproducible plans on the coordinator side of device
    // 0's link: corrupt / hang / disconnect, either direction, any of the
    // first five frames — same seed, same scenario, every run
    let reference = in_process_reference();
    for seed in 0..6u64 {
        let tag = format!("seeded_{seed}");
        let plan = FaultPlan::seeded(seed, 5, Duration::from_secs(2));
        let run = chaos_run(
            &tag,
            vec![worker_cfg(None, None), worker_cfg(None, None)],
            vec![plan],
            3,
            false,
            None,
        )
        .unwrap_or_else(|e| panic!("{tag}: must recover, got: {e}"));
        assert_recovered_bitwise(&tag, &run, &reference);
    }
}

// ---- checkpoint rollback + manifest accounting ---------------------------

#[test]
fn recovery_rolls_back_to_the_newest_checkpoint_and_records_the_fault() {
    // with per-epoch checkpointing the worker's send frames are 0 Hello,
    // 1 Assigned, 2 Ingested, 3 EpochDone(e0), 4 Exported(ckpt@1),
    // 5 EpochDone(e1): corrupting frame 5 faults *after* the epoch-1
    // checkpoint exists, so the rollback must land there — not at epoch 0
    let reference = in_process_reference();
    let store_dir = scratch("rollback_store");
    let plan = FaultPlan::one(Dir::Send, 5, FaultAction::Corrupt);
    let run = chaos_run(
        "ckpt_rollback",
        vec![worker_cfg(Some(plan), None), worker_cfg(None, None)],
        vec![],
        3,
        false,
        Some(store_dir.clone()),
    )
    .expect("must roll back to the checkpoint and recover");
    assert_recovered_bitwise("ckpt_rollback", &run, &reference);
    let fault = &run.comm.faults[0];
    assert_eq!(fault.kind, FaultKind::Corruption, "crc faults classify as corruption");
    assert_eq!(fault.restart_epoch, 1, "rollback must land on the epoch-1 checkpoint");

    // the fault survives in the run manifest, next to the checkpoints
    let store = RunStore::open(&store_dir).expect("reopen run store");
    assert_eq!(store.faults().len(), 1, "the fault must be recorded in run.json");
    assert_eq!(store.faults()[0].get("kind").as_str(), Some("corruption"));
    assert_eq!(store.latest(), Some(EPOCHS), "the recovered run still checkpoints its end");
    let _ = std::fs::remove_dir_all(&store_dir);
}

// ---- fail-fast paths -----------------------------------------------------

#[test]
fn exhausted_recovery_budget_fails_fast_with_a_classified_error() {
    let plan = FaultPlan::one(Dir::Send, 3, FaultAction::Corrupt);
    let err = chaos_run(
        "give_up",
        vec![worker_cfg(Some(plan), Some(1)), worker_cfg(None, Some(1))],
        vec![],
        0,
        false,
        None,
    )
    .expect_err("zero recovery budget must surface the fault");
    let msg = err.to_string();
    assert!(msg.contains("giving up after 0"), "{msg}");
    assert!(msg.contains("corruption"), "the error must carry the classification: {msg}");
}

#[test]
fn a_fully_dead_cluster_fails_fast_instead_of_waiting_forever() {
    // two endpoints nobody listens on: every dial is refused, every
    // recovery attempt walks both endpoints, and the run gives up within
    // its connect deadlines — bounded, classified, no workers needed
    let ports: Vec<u16> = (0..DEVICES)
        .map(|_| {
            let l = std::net::TcpListener::bind("127.0.0.1:0").expect("probe port");
            let p = l.local_addr().expect("probe addr").port();
            drop(l);
            p
        })
        .collect();
    let ds = dataset();
    let shard_dir = scratch("dead_cluster_shards");
    write_shard_set(&shard_dir, &ds, SEED);
    let endpoints: Vec<String> = ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();
    let rec = recovery(vec![], 1);
    let placement = Placement::Remote { endpoints, shards: shard_dir.clone() };
    let err = with_watchdog("dead_cluster", move || {
        let ds = dataset();
        let coord = coordinator(placement, rec);
        let prep = coord.prepare(&ds.x, &NativeBackend::default());
        coord.fit_resumable(N, &prep, None)
    })
    .expect_err("a dead cluster must not hang the coordinator");
    let msg = err.to_string();
    assert!(msg.contains("giving up after 1"), "{msg}");
    assert!(msg.contains("no endpoint accepted"), "{msg}");
    let _ = std::fs::remove_dir_all(&shard_dir);
}

// ---- unix-socket transport (reduced sweep) -------------------------------

#[cfg(unix)]
#[test]
fn unix_socket_faults_recover_bitwise() {
    let reference = in_process_reference();

    // transient corruption: recovery re-dials the same unix endpoint
    let plan = FaultPlan::one(Dir::Send, 3, FaultAction::Corrupt);
    let run = chaos_run(
        "unix_corrupt",
        vec![worker_cfg(Some(plan), None), worker_cfg(None, None)],
        vec![],
        3,
        true,
        None,
    )
    .expect("unix corruption must recover");
    assert_recovered_bitwise("unix_corrupt", &run, &reference);

    // a killed worker (socket file unlinked) rotates onto the survivor
    let plan = FaultPlan::one(Dir::Send, 1, FaultAction::Disconnect);
    let run = chaos_run(
        "unix_kill",
        vec![worker_cfg(Some(plan), Some(1)), worker_cfg(None, None)],
        vec![],
        3,
        true,
        None,
    )
    .expect("unix worker death must rotate and recover");
    assert_recovered_bitwise("unix_kill", &run, &reference);
}
