//! The zero-perturbation invariant (DESIGN.md §15): telemetry flows out of
//! training, never back in.  Fitting with the metrics registry and span
//! tracing enabled must produce **bitwise identical** positions, losses,
//! and means to fitting with both disabled.
//!
//! This lives in its own integration-test binary with a single `#[test]`
//! on purpose: `obs::metrics::set_enabled` / `obs::trace::set_enabled` are
//! process-global switches, and the default multi-threaded test harness
//! would race them across tests.  CI runs this binary at 1 and 8 threads
//! (NOMAD_THREADS), and the obs-smoke job repeats the A/B over a real
//! 2-worker multiprocess run.

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::coordinator::{NomadCoordinator, NomadRun, RunConfig};
use nomad::data::{gaussian_mixture, Dataset};
use nomad::embed::NomadParams;
use nomad::obs::{metrics, trace};
use nomad::util::rng::Rng;

fn corpus() -> Dataset {
    let mut rng = Rng::new(11);
    gaussian_mixture(600, 16, 4, 10.0, 0.2, 0.5, &mut rng)
}

fn fit_once(ds: &Dataset) -> NomadRun {
    let coord = NomadCoordinator::new(
        NomadParams { epochs: 12, k: 5, negs: 4, seed: 42, ..Default::default() },
        RunConfig {
            n_devices: 3,
            index: IndexParams { n_clusters: 4, k: 5, ..Default::default() },
            ..Default::default()
        },
    );
    coord.fit(ds, &NativeBackend::default())
}

#[test]
fn telemetry_on_vs_off_is_bitwise_identical() {
    let ds = corpus();

    metrics::set_enabled(true);
    trace::set_enabled(true);
    let on = fit_once(&ds);
    trace::set_enabled(false);
    let spans = trace::take_all();
    assert!(!spans.is_empty(), "tracing was on — the run must have recorded spans");

    metrics::set_enabled(false);
    let off = fit_once(&ds);
    metrics::set_enabled(true);

    let bits = |run: &NomadRun| -> Vec<u32> {
        run.positions.data.iter().map(|v| v.to_bits()).collect()
    };
    assert_eq!(bits(&on), bits(&off), "positions must not feel telemetry");
    assert_eq!(on.loss_history, off.loss_history, "losses must not feel telemetry");
    assert_eq!(on.final_means, off.final_means, "means must not feel telemetry");
}
