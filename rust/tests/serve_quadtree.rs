//! Property tests for the serving layer's quadtree (DESIGN.md §10): the
//! tree's range and kNN answers must match the brute-force oracles
//! **exactly** — ties included.
//!
//! Exactness strategy (same as `tests/distance_engine.rs`): most cases
//! use small-integer coordinates, where every squared distance is an
//! exact f32 integer and low-cardinality data is riddled with duplicate
//! points and genuinely tied distances — so the `(d², id)` tie contract
//! is exercised for real rather than by luck.  Point counts straddle the
//! leaf capacity (64) so both leaf scans and deep subdivision run.

use nomad::linalg::Matrix;
use nomad::serve::quadtree::{knn_naive, range_naive, Quadtree};
use nomad::util::rng::Rng;

const CASES: usize = 25;

fn int_points(rng: &mut Rng, n: usize, hi: usize) -> Matrix {
    let mut m = Matrix::zeros(n, 2);
    for v in m.data.iter_mut() {
        *v = rng.below(hi) as f32;
    }
    m
}

fn gauss_points(rng: &mut Rng, n: usize) -> Matrix {
    let mut m = Matrix::zeros(n, 2);
    for v in m.data.iter_mut() {
        *v = rng.normal() * 5.0;
    }
    m
}

#[test]
fn prop_range_matches_naive_exactly() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(300); // straddles LEAF_CAP = 64
        let hi = 2 + rng.below(12); // low cardinality -> many duplicates
        let m = int_points(&mut rng, n, hi);
        let t = Quadtree::build(&m);
        for _ in 0..8 {
            let a = rng.below(hi) as f32 - 1.0;
            let b = rng.below(hi) as f32 - 1.0;
            let w = rng.below(hi) as f32;
            let h = rng.below(hi) as f32;
            let got = t.range(a, b, a + w, b + h);
            let want = range_naive(&m, a, b, a + w, b + h);
            assert_eq!(got, want, "seed {seed} n {n} rect ({a},{b})+({w},{h})");
        }
        // degenerate rectangles: single line / single point
        let got = t.range(1.0, 0.0, 1.0, hi as f32);
        assert_eq!(got, range_naive(&m, 1.0, 0.0, 1.0, hi as f32), "seed {seed} line");
    }
}

#[test]
fn prop_knn_matches_naive_exactly_with_ties() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(100 + seed);
        let n = 1 + rng.below(300);
        let hi = 2 + rng.below(8); // dense ties
        let m = int_points(&mut rng, n, hi);
        let t = Quadtree::build(&m);
        for _ in 0..6 {
            let qx = rng.below(2 * hi) as f32 - hi as f32;
            let qy = rng.below(2 * hi) as f32 - hi as f32;
            let k = 1 + rng.below(n + 20); // sometimes k > n
            let got = t.knn(qx, qy, k);
            let want = knn_naive(&m, qx, qy, k);
            assert_eq!(got, want, "seed {seed} n {n} q ({qx},{qy}) k {k}");
        }
    }
}

#[test]
fn prop_knn_matches_on_continuous_data() {
    // gaussian coordinates: no engineered ties, but identical f32
    // arithmetic on both sides must still agree bitwise
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(200 + seed);
        let n = 1 + rng.below(500);
        let m = gauss_points(&mut rng, n);
        let t = Quadtree::build(&m);
        let (qx, qy) = (rng.normal() * 5.0, rng.normal() * 5.0);
        let k = 1 + rng.below(40);
        assert_eq!(t.knn(qx, qy, k), knn_naive(&m, qx, qy, k), "seed {seed} n {n} k {k}");
    }
}

#[test]
fn prop_range_matches_on_continuous_data() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(300 + seed);
        let n = 1 + rng.below(500);
        let m = gauss_points(&mut rng, n);
        let t = Quadtree::build(&m);
        for _ in 0..6 {
            let (cx, cy) = (rng.normal() * 3.0, rng.normal() * 3.0);
            let (w, h) = (rng.f32() * 8.0, rng.f32() * 8.0);
            let got = t.range(cx - w, cy - h, cx + w, cy + h);
            assert_eq!(got, range_naive(&m, cx - w, cy - h, cx + w, cy + h), "seed {seed}");
        }
    }
}

#[test]
fn prop_nan_rows_never_surface() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(400 + seed);
        let n = 20 + rng.below(200);
        let mut m = int_points(&mut rng, n, 6);
        // poison a third of the rows
        for i in 0..n / 3 {
            let r = rng.below(n);
            m.row_mut(r)[rng.below(2)] = if rng.f32() < 0.5 { f32::NAN } else { f32::INFINITY };
        }
        let t = Quadtree::build(&m);
        let all = t.range(f32::MIN, f32::MIN, f32::MAX, f32::MAX);
        assert_eq!(all, range_naive(&m, f32::MIN, f32::MIN, f32::MAX, f32::MAX), "seed {seed}");
        let nn = t.knn(0.0, 0.0, n);
        assert_eq!(nn, knn_naive(&m, 0.0, 0.0, n), "seed {seed}");
        assert!(nn.iter().all(|&(_, d2)| d2.is_finite()));
    }
}
