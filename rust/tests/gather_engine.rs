//! Property tests for the gather-based force engine (DESIGN.md §9).
//!
//! The gather path must (a) match the serial scatter oracle to f32
//! reassociation error on random padded problems — duplicate/tied edges,
//! self-negatives, and padding rows included; (b) agree with the retired
//! chunked scatter path (the second oracle); (c) be bitwise identical for
//! 1/2/8 worker threads — owner-computes with a fixed edge order makes
//! this hold by construction; and (d) stay NaN-free with exactly-zero
//! gradients on padding rows.

use nomad::embed::native::{nomad_grad_gather, nomad_grad_scatter, nomad_grad_serial};
use nomad::embed::EdgeTranspose;
use nomad::util::rng::Rng;

#[allow(clippy::type_complexity)]
fn random_problem(
    rng: &mut Rng,
    size: usize,
    k: usize,
    negs: usize,
    r: usize,
    n_real: usize,
) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>, f32, Vec<f32>, Vec<f32>, Vec<f32>) {
    let pos: Vec<f32> = (0..size * 2).map(|_| rng.normal() * 3.0).collect();
    let mut nbr_idx = vec![0i32; size * k];
    let mut nbr_w = vec![0.0f32; size * k];
    let mut neg_idx = vec![0i32; size * negs];
    for i in 0..size {
        for s in 0..k {
            // duplicates and self-edges happen by construction: they are
            // the tie cases the gather reaction pass must reproduce
            nbr_idx[i * k + s] = rng.below(n_real.max(1)) as i32;
            nbr_w[i * k + s] = if i < n_real && rng.f32() > 0.2 { rng.f32() } else { 0.0 };
        }
        for s in 0..negs {
            neg_idx[i * negs + s] =
                if i < n_real { rng.below(n_real.max(1)) as i32 } else { i as i32 };
        }
    }
    let neg_w = rng.f32() + 0.1;
    let means: Vec<f32> = (0..r * 2).map(|_| rng.normal() * 3.0).collect();
    let mean_w: Vec<f32> = (0..r).map(|_| rng.f32() * 4.0).collect();
    let mut valid = vec![0.0f32; size];
    for v in valid.iter_mut().take(n_real) {
        *v = 1.0;
    }
    (pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid)
}

fn soa(means: &[f32]) -> (Vec<f32>, Vec<f32>) {
    (
        means.iter().step_by(2).copied().collect(),
        means.iter().skip(1).step_by(2).copied().collect(),
    )
}

fn transposes(
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    size: usize,
    k: usize,
    negs: usize,
) -> (EdgeTranspose, EdgeTranspose) {
    (
        EdgeTranspose::build(nbr_idx, size, k, |e| nbr_w[e] != 0.0),
        EdgeTranspose::build(neg_idx, size, negs, |_| true),
    )
}

#[test]
fn prop_gather_matches_serial_oracle() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed);
        let size = 64 + rng.below(512);
        let n_real = 1 + rng.below(size);
        let k = 1 + rng.below(8);
        let negs = 1 + rng.below(6);
        let r = rng.below(70); // r = 0 covers the ApproxMode::None view
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, size, k, negs, r, n_real);
        let (nbr_in, neg_in) = transposes(&ni, &nw, &gi, size, k, negs);
        let (mx, my) = soa(&me);

        let (gs, ls) = nomad_grad_serial(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, k, negs);
        let (gg, lg) = nomad_grad_gather(
            &pos, &ni, &nw, &nbr_in, &gi, &neg_in, gw, &mx, &my, &mw, &va, k, negs, 4,
        );
        assert!(
            (ls - lg).abs() < 1e-5 * (1.0 + ls.abs()),
            "seed {seed}: loss serial {ls} vs gather {lg}"
        );
        for i in 0..size * 2 {
            assert!(gg[i].is_finite(), "seed {seed} coord {i}: gather NaN/inf");
            let d = (gs[i] - gg[i]).abs();
            assert!(
                d < 1e-5 * (1.0 + gs[i].abs()),
                "seed {seed} coord {i}: serial {} gather {}",
                gs[i],
                gg[i]
            );
        }
        // padding rows: exactly zero, not merely small
        for l in n_real..size {
            assert_eq!(gg[l * 2], 0.0, "seed {seed}: padding row {l} moved");
            assert_eq!(gg[l * 2 + 1], 0.0, "seed {seed}: padding row {l} moved");
        }
    }
}

#[test]
fn prop_gather_matches_scatter_second_oracle() {
    for seed in 100..112u64 {
        let mut rng = Rng::new(seed);
        let size = 256 + rng.below(512);
        let n_real = size - rng.below(size / 4);
        let (k, negs, r) = (1 + rng.below(8), 1 + rng.below(6), 1 + rng.below(40));
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, size, k, negs, r, n_real);
        let (nbr_in, neg_in) = transposes(&ni, &nw, &gi, size, k, negs);
        let (mx, my) = soa(&me);

        let (gp, lp) = nomad_grad_scatter(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, k, negs, 8);
        let (gg, lg) = nomad_grad_gather(
            &pos, &ni, &nw, &nbr_in, &gi, &neg_in, gw, &mx, &my, &mw, &va, k, negs, 8,
        );
        assert!((lp - lg).abs() < 2e-5 * (1.0 + lp.abs()), "seed {seed}: {lp} vs {lg}");
        for i in 0..size * 2 {
            let d = (gp[i] - gg[i]).abs();
            assert!(
                d < 2e-5 * (1.0 + gp[i].abs()),
                "seed {seed} coord {i}: scatter {} gather {}",
                gp[i],
                gg[i]
            );
        }
    }
}

#[test]
fn prop_gather_bitwise_invariant_to_thread_count() {
    for seed in 200..210u64 {
        let mut rng = Rng::new(seed);
        let size = 64 + rng.below(700);
        let n_real = 1 + rng.below(size);
        let (k, negs, r) = (1 + rng.below(8), 1 + rng.below(6), rng.below(40));
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, size, k, negs, r, n_real);
        let (nbr_in, neg_in) = transposes(&ni, &nw, &gi, size, k, negs);
        let (mx, my) = soa(&me);
        let run = |threads: usize| {
            nomad_grad_gather(
                &pos, &ni, &nw, &nbr_in, &gi, &neg_in, gw, &mx, &my, &mw, &va, k, negs, threads,
            )
        };
        let (g1, l1) = run(1);
        let (g2, l2) = run(2);
        let (g8, l8) = run(8);
        assert_eq!(g1, g2, "seed {seed}: 1 vs 2 workers not bitwise identical");
        assert_eq!(g2, g8, "seed {seed}: 2 vs 8 workers not bitwise identical");
        assert_eq!(l1.to_bits(), l2.to_bits(), "seed {seed}: loss differs");
        assert_eq!(l2.to_bits(), l8.to_bits(), "seed {seed}: loss differs");
    }
}

#[test]
fn gather_handles_self_negatives_and_duplicate_edges() {
    // hand-built worst case: every head's negatives are itself, and the
    // edge list repeats one (i, j) pair with tied weights both directions
    let size = 4usize;
    let (k, negs) = (3usize, 2usize);
    let pos = vec![0.0f32, 0.0, 1.0, 0.5, -0.5, 2.0, 0.3, -0.7];
    let nbr_idx = vec![1, 1, 2, 0, 0, 3, 1, 3, 0, 2, 1, 0];
    let nbr_w = vec![0.25f32, 0.25, 0.5, 0.5, 0.5, 0.0, 0.3, 0.3, 0.4, 0.2, 0.2, 0.6];
    let neg_idx = vec![0i32, 1, 1, 0, 2, 3, 3, 2];
    let (neg_w, mw) = (0.7f32, vec![1.5f32]);
    let me = vec![2.0f32, -1.0];
    let va = vec![1.0f32; size];

    let (nbr_in, neg_in) = transposes(&nbr_idx, &nbr_w, &neg_idx, size, k, negs);
    let (mx, my) = soa(&me);
    let (gs, ls) =
        nomad_grad_serial(&pos, &nbr_idx, &nbr_w, &neg_idx, neg_w, &me, &mw, &va, k, negs);
    let (gg, lg) = nomad_grad_gather(
        &pos, &nbr_idx, &nbr_w, &nbr_in, &neg_idx, &neg_in, neg_w, &mx, &my, &mw, &va, k, negs, 2,
    );
    assert!((ls - lg).abs() < 1e-6 * (1.0 + ls.abs()), "loss {ls} vs {lg}");
    for i in 0..size * 2 {
        assert!(gg[i].is_finite());
        assert!(
            (gs[i] - gg[i]).abs() < 1e-5 * (1.0 + gs[i].abs()),
            "coord {i}: serial {} gather {}",
            gs[i],
            gg[i]
        );
    }
}

#[test]
fn gather_with_zero_negative_weight_skips_repulsion_reactions() {
    // neg_w = 0 (mean-only negative mass): the repulsion coefficients are
    // all zero and the gather result must still match the oracle exactly
    let mut rng = Rng::new(77);
    let (size, k, negs, r, n_real) = (96usize, 4usize, 3usize, 9usize, 80usize);
    let (pos, ni, nw, gi, _, me, mw, va) = random_problem(&mut rng, size, k, negs, r, n_real);
    let (nbr_in, neg_in) = transposes(&ni, &nw, &gi, size, k, negs);
    let (mx, my) = soa(&me);
    let (gs, ls) = nomad_grad_serial(&pos, &ni, &nw, &gi, 0.0, &me, &mw, &va, k, negs);
    let (gg, lg) = nomad_grad_gather(
        &pos, &ni, &nw, &nbr_in, &gi, &neg_in, 0.0, &mx, &my, &mw, &va, k, negs, 3,
    );
    assert!((ls - lg).abs() < 1e-6 * (1.0 + ls.abs()));
    for i in 0..size * 2 {
        assert!((gs[i] - gg[i]).abs() < 1e-5 * (1.0 + gs[i].abs()), "coord {i}");
    }
}
