//! Kill-and-resume property test (DESIGN.md §11): for **every**
//! checkpoint epoch of a small run, drop the coordinator (simulated by a
//! fresh coordinator + fresh `prepare` — nothing survives but the run
//! store on disk), `resume_from` that checkpoint, and assert the final
//! positions, loss history, and means table are **bitwise equal** to the
//! uninterrupted run — at 1, 2, and 8 worker threads.
//!
//! (The thread-count env juggling is safe alongside the other tests in
//! this binary because results are bitwise thread-invariant by contract;
//! the variable only shifts scheduling.)

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::checkpoint::{params_fingerprint, run_info_json, DatasetSpec, RunStore};
use nomad::coordinator::{CheckpointCfg, NomadCoordinator, RunConfig};
use nomad::data::{gaussian_mixture, Dataset};
use nomad::embed::NomadParams;
use nomad::util::json::Json;
use nomad::util::rng::Rng;
use std::path::PathBuf;

const EPOCHS: usize = 8;

fn corpus() -> Dataset {
    let mut rng = Rng::new(11);
    gaussian_mixture(300, 10, 3, 9.0, 0.1, 0.4, &mut rng)
}

fn params() -> NomadParams {
    NomadParams { epochs: EPOCHS, k: 4, negs: 3, seed: 77, ..Default::default() }
}

fn run_config(n_devices: usize) -> RunConfig {
    RunConfig {
        n_devices,
        index: IndexParams { n_clusters: 3, k: 4, ..Default::default() },
        ..Default::default()
    }
}

fn coordinator() -> NomadCoordinator {
    NomadCoordinator::new(params(), run_config(2))
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("nomad_ckpt_resume").join(name);
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn make_store(dir: &PathBuf, ds: &Dataset, coord: &NomadCoordinator) -> RunStore {
    let fp = params_fingerprint(ds.n(), &coord.params, &coord.run.index);
    let spec = DatasetSpec { kind: "synthetic".into(), source: "test".into(), n: ds.n(), seed: 11 };
    let info = run_info_json(ds.n(), coord.run.n_devices, &coord.params, &coord.run.index, &spec);
    RunStore::create(dir, fp, info).unwrap()
}

fn ckpt_cfg(every: usize) -> CheckpointCfg {
    CheckpointCfg { every, retain: 0, artifact: false, labels: None, dataset: "test".into() }
}

#[test]
fn resume_from_every_checkpoint_is_bitwise_identical() {
    let ds = corpus();
    for threads in [1usize, 2, 8] {
        std::env::set_var("NOMAD_THREADS", threads.to_string());
        let dir = tmp(&format!("prop-{threads}t"));

        // the uninterrupted run, checkpointing every 2 epochs
        let coord = coordinator();
        let mut store = make_store(&dir, &ds, &coord);
        let prep = coord.prepare(&ds.x, &NativeBackend::default());
        let full =
            coord.fit_resumable(ds.n(), &prep, Some((&mut store, &ckpt_cfg(2)))).unwrap();
        assert_eq!(full.loss_history.len(), EPOCHS);

        // every even epoch plus the final epoch was checkpointed
        let reopened = RunStore::open(&dir).unwrap();
        assert_eq!(reopened.checkpoints(), &[2, 4, 6, 8], "@{threads}t");

        for &e in reopened.checkpoints() {
            // "kill": everything in memory is gone; only the store remains
            let coord2 = coordinator();
            let prep2 = coord2.prepare(&ds.x, &NativeBackend::default());
            let state = reopened.load(e).unwrap();
            assert_eq!(state.epochs_done, e);
            // the stored loss prefix matches the full run's exactly
            for (a, b) in state.loss_history.iter().zip(&full.loss_history) {
                assert_eq!(a.to_bits(), b.to_bits(), "loss prefix @{threads}t epoch {e}");
            }
            let resumed = coord2.resume_from(ds.n(), &prep2, state, None).unwrap();
            assert_eq!(
                resumed.positions.data, full.positions.data,
                "positions must be bitwise equal resuming from epoch {e} @{threads}t"
            );
            assert_eq!(
                resumed.loss_history, full.loss_history,
                "loss history must be bitwise equal resuming from epoch {e} @{threads}t"
            );
            assert_eq!(
                resumed.final_means, full.final_means,
                "means table must be bitwise equal resuming from epoch {e} @{threads}t"
            );
        }
    }
    std::env::remove_var("NOMAD_THREADS");
}

#[test]
fn resume_under_different_params_is_refused() {
    let ds = corpus();
    let dir = tmp("fingerprint");
    let coord = coordinator();
    let mut store = make_store(&dir, &ds, &coord);
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    coord.fit_resumable(ds.n(), &prep, Some((&mut store, &ckpt_cfg(4)))).unwrap();
    let state = store.load_latest().unwrap();

    // different seed -> different fingerprint -> refuse
    let other = NomadCoordinator::new(NomadParams { seed: 78, ..params() }, run_config(2));
    let prep2 = other.prepare(&ds.x, &NativeBackend::default());
    let e = other.resume_from(ds.n(), &prep2, state.clone(), None);
    assert!(e.is_err(), "seed change must refuse to resume");
    assert!(e.unwrap_err().to_string().contains("fingerprint"));

    // different index config -> refuse
    let other = NomadCoordinator::new(params(), run_config(2));
    let mut rc = other.run.clone();
    rc.index.n_clusters = 4;
    let other = NomadCoordinator::new(params(), rc);
    let prep3 = other.prepare(&ds.x, &NativeBackend::default());
    assert!(other.resume_from(ds.n(), &prep3, state, None).is_err());
}

#[test]
fn resume_from_the_final_checkpoint_returns_the_final_state() {
    let ds = corpus();
    let dir = tmp("final");
    let coord = coordinator();
    let mut store = make_store(&dir, &ds, &coord);
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let full = coord.fit_resumable(ds.n(), &prep, Some((&mut store, &ckpt_cfg(3)))).unwrap();
    // every=3 over 8 epochs -> 3, 6, and the always-written final 8
    assert_eq!(store.checkpoints(), &[3, 6, 8]);

    let state = store.load(EPOCHS).unwrap();
    let coord2 = coordinator();
    let prep2 = coord2.prepare(&ds.x, &NativeBackend::default());
    let resumed = coord2.resume_from(ds.n(), &prep2, state, None).unwrap();
    assert_eq!(resumed.positions.data, full.positions.data);
    assert_eq!(resumed.loss_history, full.loss_history);
}

#[test]
fn retention_keeps_resumability_from_recent_checkpoints() {
    let ds = corpus();
    let dir = tmp("retention");
    let coord = coordinator();
    let mut store = make_store(&dir, &ds, &coord);
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let cfg = CheckpointCfg { every: 2, retain: 2, ..ckpt_cfg(2) };
    let full = coord.fit_resumable(ds.n(), &prep, Some((&mut store, &cfg))).unwrap();
    assert_eq!(store.checkpoints(), &[6, 8], "only the newest 2 survive");
    assert!(store.load(2).is_err(), "pruned checkpoints are gone");
    let resumed = coord
        .resume_from(ds.n(), &prep, store.load(6).unwrap(), None)
        .unwrap();
    assert_eq!(resumed.positions.data, full.positions.data);
}

#[test]
fn run_info_in_the_store_rebuilds_the_run() {
    // what `nomad resume` does: everything needed comes from run.json
    let ds = corpus();
    let dir = tmp("runinfo");
    let coord = coordinator();
    let mut store = make_store(&dir, &ds, &coord);
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let full = coord.fit_resumable(ds.n(), &prep, Some((&mut store, &ckpt_cfg(4)))).unwrap();

    let reopened = RunStore::open(&dir).unwrap();
    let (n, n_devices, p2, idx2, spec) =
        nomad::checkpoint::parse_run_info(reopened.run_info()).unwrap();
    assert_eq!((n, n_devices), (ds.n(), 2));
    assert_eq!(spec.source, "test");
    assert_eq!(
        params_fingerprint(n, &p2, &idx2),
        reopened.fingerprint(),
        "round-tripped params must reproduce the stored fingerprint"
    );
    // and the rebuilt coordinator resumes bitwise-identically
    let coord2 = NomadCoordinator::new(
        p2,
        RunConfig { n_devices, index: idx2, ..Default::default() },
    );
    let prep2 = coord2.prepare(&ds.x, &NativeBackend::default());
    let resumed = coord2
        .resume_from(ds.n(), &prep2, reopened.load(4).unwrap(), None)
        .unwrap();
    assert_eq!(resumed.positions.data, full.positions.data);
    assert_eq!(resumed.loss_history, full.loss_history);
}

#[test]
fn corrupt_or_missing_store_surfaces_as_errors_everywhere() {
    let ds = corpus();
    let dir = tmp("corrupt");
    let coord = coordinator();
    let mut store = make_store(&dir, &ds, &coord);
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    coord.fit_resumable(ds.n(), &prep, Some((&mut store, &ckpt_cfg(4)))).unwrap();

    // truncate run.json mid-byte: open must Err, not panic
    let manifest = dir.join("run.json");
    let orig = std::fs::read_to_string(&manifest).unwrap();
    std::fs::write(&manifest, &orig[..orig.len() / 2]).unwrap();
    assert!(RunStore::open(&dir).is_err());
    std::fs::write(&manifest, &orig).unwrap();

    // checkpoint listed in the manifest but deleted on disk
    let reopened = RunStore::open(&dir).unwrap();
    std::fs::remove_dir_all(reopened.ckpt_dir(4)).unwrap();
    assert!(reopened.load(4).is_err());
    assert!(reopened.load_latest().is_err(), "latest points at the deleted epoch");
}

#[test]
fn store_checkpoints_can_coexist_with_a_plain_fit() {
    // fit_prepared (no sink) must behave exactly as before the refactor
    let ds = corpus();
    let coord = coordinator();
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let a = coord.fit_prepared(ds.n(), &prep);
    let dir = tmp("coexist");
    let mut store = make_store(&dir, &ds, &coord);
    let b = coord.fit_resumable(ds.n(), &prep, Some((&mut store, &ckpt_cfg(2)))).unwrap();
    assert_eq!(a.positions.data, b.positions.data, "checkpointing must not change results");
    assert_eq!(a.loss_history, b.loss_history);

    // and a Json sanity check on what landed on disk
    let text = std::fs::read_to_string(dir.join("run.json")).unwrap();
    let v = Json::parse(&text).unwrap();
    assert_eq!(v.get("format").as_str(), Some("nomad-run-store"));
    assert_eq!(v.get("latest").as_usize(), Some(EPOCHS));
}
