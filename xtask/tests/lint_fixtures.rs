//! Fixture tests for the invariant linter: every rule must fire on a
//! seeded violation and stay silent on the compliant twin, the pragma
//! machinery must suppress exactly what it names, and the lexer must not
//! trip on tokens hidden in strings or comments.

use xtask::lint::lint_source;

/// Lint `src` as if it lived at `rel`, returning `(line, rule_id)` pairs.
fn lint(rel: &str, src: &str) -> Vec<(usize, String)> {
    lint_source(rel, src)
        .violations
        .into_iter()
        .map(|v| (v.line, v.rule.id().to_string()))
        .collect()
}

fn rules(rel: &str, src: &str) -> Vec<String> {
    lint(rel, src).into_iter().map(|(_, r)| r).collect()
}

// ------------------------------------------------------------ rule (a)

#[test]
fn unsafe_without_safety_comment_fires() {
    let src = "fn f(p: *mut u8) {\n    unsafe { *p = 0; }\n}\n";
    assert_eq!(lint("embed/x.rs", src), vec![(2, "safety_comment".to_string())]);
}

#[test]
fn unsafe_with_safety_line_above_is_clean() {
    let src =
        "fn f(p: *mut u8) {\n    // SAFETY: p is valid for writes\n    unsafe { *p = 0; }\n}\n";
    assert!(lint("embed/x.rs", src).is_empty());
}

#[test]
fn unsafe_with_same_line_safety_is_clean() {
    let src = "fn f(p: *mut u8) {\n    unsafe { *p = 0; } // SAFETY: p is valid\n}\n";
    assert!(lint("embed/x.rs", src).is_empty());
}

#[test]
fn safety_walk_skips_attributes_and_comment_lines() {
    let src = "\
// SAFETY: justified at length
// over two comment lines
#[inline]
unsafe fn g() {}
";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn one_safety_comment_covers_chained_unsafe_impl_pair() {
    let src = "\
struct S(*mut u8);
// SAFETY: the pointer is never written through
unsafe impl Send for S {}
unsafe impl Sync for S {}
";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn blank_line_breaks_safety_adjacency() {
    let src = "// SAFETY: stale comment\n\nunsafe fn g() {}\n";
    assert_eq!(rules("a.rs", src), vec!["safety_comment"]);
}

#[test]
fn multiline_unsafe_block_only_flags_opening_line() {
    let src = "\
fn f(p: *mut u8) {
    let v = unsafe {
        *p
    };
}
";
    assert_eq!(lint("a.rs", src), vec![(2, "safety_comment".to_string())]);
}

#[test]
fn safety_applies_inside_tests_too() {
    let src = "\
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        unsafe { std::hint::unreachable_unchecked() }
    }
}
";
    assert_eq!(rules("a.rs", src), vec!["safety_comment"]);
}

// ------------------------------------------------------ lexer traps

#[test]
fn unsafe_in_string_literal_is_ignored() {
    let src = "fn f() { let s = \"this unsafe word\"; let _ = s; }\n";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn unsafe_in_raw_string_is_ignored() {
    let src = "fn f() { let s = r#\"unsafe { }\"#; let _ = s; }\n";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn unsafe_in_comment_is_ignored() {
    let src = "// this mentions unsafe code but contains none\nfn f() {}\n/* unsafe here too */\n";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn unsafe_as_identifier_substring_is_ignored() {
    let src = "fn f() { let not_unsafe_flag = 1; let _ = not_unsafe_flag; }\n";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn char_literal_quote_does_not_eat_rest_of_line() {
    // a char literal containing '"' must not open a string state
    let src = "fn f() { let c = '\"'; let _ = (c, \"unsafe\"); }\n";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn lifetime_is_not_a_char_literal() {
    // if 'a were lexed as a char opening, the rest of the file would be
    // swallowed and the real violation below would be missed
    let src = "fn f<'a>(x: &'a u32) -> &'a u32 { x }\nfn g() { unsafe { } }\n";
    assert_eq!(lint("a.rs", src), vec![(2, "safety_comment".to_string())]);
}

// ------------------------------------------------------------ rule (b)

#[test]
fn partial_cmp_fires_everywhere_even_in_tests() {
    let src = "\
#[cfg(test)]
mod tests {
    fn t(a: f32, b: f32) { let _ = a.partial_cmp(&b); }
}
";
    assert_eq!(rules("serve/x.rs", src), vec!["partial_cmp"]);
}

#[test]
fn partial_cmp_definition_is_allowed() {
    let src = "fn partial_cmp(a: u8, b: u8) -> u8 { a + b }\n";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn float_sort_without_total_order_fires() {
    let src = "fn f(v: &mut [f32]) { v.sort_by(|a, b| b.abs().cmp2(&a.abs())); }\n";
    assert_eq!(rules("a.rs", src), vec!["float_sort"]);
}

#[test]
fn float_sort_with_total_cmp_is_clean() {
    let src = "fn f(v: &mut [f32]) { v.sort_by(|a, b| a.total_cmp(b)); }\n";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn float_sort_multiline_comparator_is_scanned_to_closing_paren() {
    let src = "\
fn f(v: &mut [(f32, u32)]) {
    v.sort_by(|a, b| {
        a.0.total_cmp(&b.0)
    });
}
";
    assert!(lint("a.rs", src).is_empty());
}

#[test]
fn sort_unstable_by_is_covered() {
    let src = "fn f(v: &mut [f32]) { v.sort_unstable_by(|a, b| cmp2(a, b)); }\n";
    assert_eq!(rules("a.rs", src), vec!["float_sort"]);
}

// ------------------------------------------------------------ rule (c)

#[test]
fn determinism_rules_fire_only_in_critical_modules() {
    let src = "\
use std::collections::HashMap;
fn f() {
    let t = std::time::Instant::now();
    let m: HashMap<u32, u32> = HashMap::new();
    let id = std::thread::current().id();
    let _ = (t, m, id);
}
";
    let critical = rules("coordinator/mod.rs", src);
    assert_eq!(critical, vec!["det_hash", "det_time", "det_hash", "det_thread"]);
    // the same source in a plain module is clean (serve/ would still
    // catch the clock read under rule (e) — see the obs_sink fixtures)
    assert!(lint("harness.rs", src).is_empty());
}

#[test]
fn determinism_rules_exempt_test_modules() {
    let src = "\
fn prod() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _ = std::time::Instant::now();
        let _: std::collections::HashSet<u8> = Default::default();
    }
}
";
    assert!(lint("embed/native.rs", src).is_empty());
}

#[test]
fn critical_scope_includes_wire_and_shard_codecs() {
    let src = "fn f() { let _ = std::time::Instant::now(); }\n";
    assert_eq!(rules("distributed/proto.rs", src), vec!["det_time"]);
    assert_eq!(rules("data/shard.rs", src), vec!["det_time"]);
    // the rest of distributed/ hands the same token off to rule (e)
    assert_eq!(rules("distributed/worker.rs", src), vec!["obs_sink"]);
}

// ------------------------------------------------------------ rule (e)

#[test]
fn obs_sink_bans_raw_clock_reads_in_service_modules() {
    let src = "\
fn f() {
    let t = std::time::Instant::now();
    let s = std::time::SystemTime::now();
    let _ = (t, s);
}
";
    let want = vec![(2, "obs_sink".to_string()), (3, "obs_sink".to_string())];
    assert_eq!(lint("serve/http.rs", src), want);
    assert_eq!(rules("distributed/transport.rs", src), vec!["obs_sink", "obs_sink"]);
    assert_eq!(rules("obs/trace.rs", src), vec!["obs_sink", "obs_sink"]);
    // plain modules are untouched; proto.rs stays det_time's (no double flag)
    assert!(lint("harness.rs", src).is_empty());
    assert_eq!(rules("distributed/proto.rs", src), vec!["det_time", "det_time"]);
}

#[test]
fn obs_sink_allows_the_sanctioned_stopwatch() {
    let src = "\
use crate::util::clock::Stopwatch;
fn f() -> f64 {
    let t0 = Stopwatch::start();
    t0.secs()
}
";
    assert!(lint("serve/cache.rs", src).is_empty());
}

#[test]
fn obs_sink_exempts_test_modules_and_honors_pragmas() {
    let tests_only = "\
fn prod() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _ = std::time::Instant::now();
    }
}
";
    assert!(lint("distributed/device.rs", tests_only).is_empty());
    let pragma = "\
fn f() {
    // lint: allow(obs_sink, reason = \"boot-time banner, outside any timed phase\")
    let _ = std::time::Instant::now();
}
";
    assert!(lint("serve/http.rs", pragma).is_empty());
}

// ------------------------------------------------------------ rule (d)

#[test]
fn parser_panics_fire_in_parser_files_only() {
    let src = "fn f(v: Option<u8>) -> u8 { v.unwrap() }\n";
    assert_eq!(rules("cli.rs", src), vec!["parser_panic"]);
    assert!(lint("viz/png.rs", src).is_empty());
}

#[test]
fn lock_poison_unwrap_is_allowed() {
    let src = "\
fn f(m: &std::sync::Mutex<u8>, r: &std::sync::RwLock<u8>) -> u8 {
    *m.lock().unwrap() + *r.read().unwrap() + { *r.write().unwrap() }
}
";
    assert!(lint("serve/http.rs", src).is_empty());
}

#[test]
fn expect_and_panic_macros_fire() {
    let src = "\
fn f(v: Option<u8>) -> u8 {
    if v.is_none() { panic!(\"no\"); }
    v.expect(\"checked\")
}
";
    let got = rules("serve/http.rs", src);
    assert_eq!(got, vec!["parser_panic", "parser_panic"]);
}

#[test]
fn debug_assert_is_not_assert() {
    let src = "fn f(n: usize) { debug_assert!(n < 10); debug_assert_eq!(n, n); }\n";
    assert!(lint("cli.rs", src).is_empty());
}

#[test]
fn assert_macros_fire_in_parsers() {
    let src = "fn f(n: usize) { assert!(n < 10); assert_eq!(n, n); assert_ne!(n, 1); }\n";
    assert_eq!(rules("util/npy.rs", src), vec!["parser_panic"; 3]);
}

#[test]
fn parser_rules_exempt_tests() {
    let src = "\
fn prod() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { assert_eq!(Some(1).unwrap(), 1); }
}
";
    assert!(lint("cli.rs", src).is_empty());
}

#[test]
fn computed_index_fires_in_byte_parsers_only() {
    let src = "fn f(b: &[u8], off: usize) -> u8 { b[off] }\n";
    assert_eq!(rules("util/npy.rs", src), vec!["parser_index"]);
    assert_eq!(rules("data/shard.rs", src), vec!["parser_index"]);
    // http/cli parse &str by splitting; the index ban does not apply
    assert!(lint("serve/http.rs", src).is_empty());
}

#[test]
fn literal_and_const_indices_are_allowed() {
    let src = "\
const HEADER: usize = 16;
fn f(b: &[u8]) -> u8 {
    let _ = &b[0..4];
    let _ = &b[..HEADER];
    let _ = &b[HEADER..];
    b[12]
}
";
    assert!(lint("util/npy.rs", src).is_empty());
}

#[test]
fn computed_range_index_fires() {
    let src = "fn f(b: &[u8], lo: usize, hi: usize) -> &[u8] { &b[lo..hi] }\n";
    assert_eq!(rules("data/shard.rs", src), vec!["parser_index"]);
}

#[test]
fn array_type_brackets_are_not_indexing() {
    let src = "fn f() -> [u8; 4] { let h: [u8; 4] = [0; 4]; h }\n";
    assert!(lint("util/npy.rs", src).is_empty());
}

// ------------------------------------------------------------ rule (f)

#[test]
fn simd_arch_fires_outside_the_kernel_module() {
    let ident = "fn f(x: f32) -> f32 { crate::helpers::_mm256_frob(x) }\n";
    assert_eq!(rules("linalg/distance.rs", ident), vec!["simd_arch"]);
    let attr = "#[target_feature(enable = \"avx2\")]\nfn g() {}\n";
    assert_eq!(rules("embed/native.rs", attr), vec!["simd_arch"]);
    let path = "fn f() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }\n";
    assert_eq!(rules("a.rs", path), vec!["simd_arch"]);
    // prose mentions are fine — only the code stream is scanned
    assert!(lint("a.rs", "// _mm256_add_ps discussed in a comment\nfn f() {}\n").is_empty());
}

#[test]
fn simd_arch_is_exempt_in_the_kernel_module() {
    let src = "\
// SAFETY: caller proved the avx2 target feature is available
#[target_feature(enable = \"avx2\")]
unsafe fn d(a: &[f32]) -> f32 { a[0] }
";
    assert!(lint("linalg/simd.rs", src).is_empty());
}

#[test]
fn simd_arch_pragma_suppresses() {
    let src = "\
// lint: allow(simd_arch, reason = \"names the intrinsic in a diagnostic string builder\")
fn f() -> &'static str { stringify!(_mm256_add_ps) }
";
    let out = lint_source("a.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    assert_eq!(out.pragmas_used, 1);
}

// ------------------------------------------------------------- pragmas

#[test]
fn pragma_on_same_line_suppresses_and_is_counted() {
    let src =
        "fn f(p: *mut u8) { unsafe { *p = 0; } } // lint: allow(safety_comment, reason = \"fixture\")\n";
    let out = lint_source("a.rs", src);
    assert!(out.violations.is_empty());
    assert_eq!(out.pragmas_used, 1);
}

#[test]
fn pragma_on_line_above_suppresses() {
    let src = "\
// lint: allow(det_time, reason = \"deadline only, never feeds numerics\")
fn f() { let _ = std::time::Instant::now(); }
";
    let out = lint_source("coordinator/mod.rs", src);
    assert!(out.violations.is_empty());
    assert_eq!(out.pragmas_used, 1);
}

#[test]
fn pragma_only_suppresses_its_named_rule() {
    let src = "\
// lint: allow(det_time, reason = \"wrong rule for this line\")
fn f(v: &mut [f32]) { v.sort_by(|a, b| cmp2(a, b)); }
";
    // the float_sort violation survives AND the pragma is flagged unused
    let got = rules("a.rs", src);
    assert!(got.contains(&"float_sort".to_string()), "{got:?}");
    assert!(got.contains(&"pragma".to_string()), "{got:?}");
}

#[test]
fn unused_pragma_is_an_error() {
    let src = "// lint: allow(partial_cmp, reason = \"nothing here uses it\")\nfn f() {}\n";
    assert_eq!(lint("a.rs", src), vec![(1, "pragma".to_string())]);
}

#[test]
fn malformed_pragmas_are_errors() {
    for bad in [
        "// lint: allow(unknown_rule, reason = \"x\")\n",
        "// lint: allow(partial_cmp)\n",
        "// lint: allow(partial_cmp, reason = )\n",
        "// lint: allow(partial_cmp, reason = \"\")\n",
        "// lint: deny(partial_cmp, reason = \"x\")\n",
    ] {
        assert_eq!(rules("a.rs", bad), vec!["pragma"], "fixture: {bad}");
    }
}

#[test]
fn pragma_does_not_reach_two_lines_down() {
    let src = "\
// lint: allow(partial_cmp, reason = \"too far away\")

fn f(a: f32, b: f32) { let _ = a.partial_cmp(&b); }
";
    let got = rules("a.rs", src);
    assert!(got.contains(&"partial_cmp".to_string()), "{got:?}");
    assert!(got.contains(&"pragma".to_string()), "{got:?}");
}

// -------------------------------------------------------- end to end

#[test]
fn seeded_multi_rule_fixture_reports_every_violation_in_line_order() {
    let src = "\
use std::collections::HashMap;
fn f(b: &[u8], off: usize) -> u8 {
    let m: HashMap<u8, u8> = HashMap::new();
    let _ = (m, std::time::Instant::now());
    unsafe { std::hint::unreachable_unchecked() };
    b[off]
}
";
    let got = lint("data/shard.rs", src);
    let lines: Vec<usize> = got.iter().map(|(l, _)| *l).collect();
    assert_eq!(lines, {
        let mut s = lines.clone();
        s.sort_unstable();
        s
    });
    let ids: Vec<&str> = got.iter().map(|(_, r)| r.as_str()).collect();
    assert_eq!(
        ids,
        vec!["det_hash", "det_hash", "det_time", "safety_comment", "parser_index"]
    );
}
