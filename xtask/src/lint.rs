//! Line-oriented invariant linter for `rust/src` (DESIGN.md §14).
//!
//! Every headline property of this reproduction — bitwise thread-count
//! invariance, bitwise multi-process equality, bitwise checkpoint
//! recovery — rests on hand-written source-level invariants: the
//! `(d², index)` tie contract, the `(device, epoch, block)` RNG contract,
//! disjoint-slot unsafe dispatch in `par_map_mut`, and no-panic parsing of
//! untrusted bytes.  This linter turns those from discipline into a gate.
//!
//! The scanner is deliberately *not* a Rust parser: it lexes just enough
//! (strings, char literals vs lifetimes, nested block comments,
//! `#[cfg(test)]` regions, brace/paren depth) to match tokens in real code
//! without tripping on `"unsafe"` inside a string literal or `HashMap` in
//! prose.  Heuristic limits are documented on each rule; the escape hatch
//! for a justified exception is an explicit, counted pragma on the same
//! line or the line above:
//!
//! ```text
//! // lint: allow(det_time, reason = "wall-clock deadline, never feeds numerics")
//! ```
//!
//! A pragma that suppresses nothing is itself an error, so stale
//! exceptions cannot linger.

use std::path::{Path, PathBuf};

/// One enforced rule.  `id()` is the name pragmas must use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// (a) every `unsafe` block / fn / impl needs an immediately preceding
    /// `// SAFETY:` comment.
    SafetyComment,
    /// (b) `.partial_cmp(...)` is banned everywhere: with `unwrap` it
    /// panics on NaN, with `unwrap_or` it silently breaks the tie
    /// contract.  Use `total_cmp` or a derived total order.
    PartialCmp,
    /// (b) `sort_by`/`sort_unstable_by` must use a total-order comparator
    /// (`total_cmp`, `Ord::cmp`, `Reverse`).
    FloatSort,
    /// (c) direct clock reads (`Instant::now`, `SystemTime`) are banned in
    /// determinism-critical modules; go through `util::clock`.
    DetTime,
    /// (c) `HashMap`/`HashSet` are banned in determinism-critical modules
    /// (iteration order varies run to run); use `BTreeMap`/`BTreeSet` or a
    /// sorted Vec.
    DetHash,
    /// (c) thread-identity reads (`thread::current`, `ThreadId`) are
    /// banned in determinism-critical modules.
    DetThread,
    /// (d) `unwrap`/`expect`/`panic!`-family calls are banned in
    /// untrusted-input parsers (lock-poison `.lock().unwrap()` and
    /// `debug_assert!` excepted).
    ParserPanic,
    /// (d) computed slice indices are banned in byte-level parsers;
    /// literal or SCREAMING_CASE-const indices into length-checked
    /// headers are allowed, everything else must use `.get()`.
    ParserIndex,
    /// (e) raw clock reads (`Instant::now`, `SystemTime`) are banned in
    /// the instrumented service modules (`serve/`, `distributed/`): obs is
    /// the one sanctioned telemetry sink (DESIGN.md §15), and its
    /// zero-perturbation A/B gate only covers time taken through
    /// `util::clock::Stopwatch`.
    ObsSink,
    /// (f) raw SIMD — `std::arch`/`core::arch` paths, `_mm*` intrinsic
    /// names, `#[target_feature]` — is banned outside `linalg/simd.rs`:
    /// the bitwise scalar/vector equivalence contract (DESIGN.md §16) is
    /// only audited there, and a stray intrinsic elsewhere would dodge it.
    SimdArch,
    /// A malformed or unused `lint: allow` pragma (not suppressible).
    Pragma,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::SafetyComment => "safety_comment",
            Rule::PartialCmp => "partial_cmp",
            Rule::FloatSort => "float_sort",
            Rule::DetTime => "det_time",
            Rule::DetHash => "det_hash",
            Rule::DetThread => "det_thread",
            Rule::ParserPanic => "parser_panic",
            Rule::ParserIndex => "parser_index",
            Rule::ObsSink => "obs_sink",
            Rule::SimdArch => "simd_arch",
            Rule::Pragma => "pragma",
        }
    }

    pub fn from_id(s: &str) -> Option<Rule> {
        Some(match s {
            "safety_comment" => Rule::SafetyComment,
            "partial_cmp" => Rule::PartialCmp,
            "float_sort" => Rule::FloatSort,
            "det_time" => Rule::DetTime,
            "det_hash" => Rule::DetHash,
            "det_thread" => Rule::DetThread,
            "parser_panic" => Rule::ParserPanic,
            "parser_index" => Rule::ParserIndex,
            "obs_sink" => Rule::ObsSink,
            "simd_arch" => Rule::SimdArch,
            _ => return None,
        })
    }
}

/// One rule violation at a 1-based source line.
#[derive(Debug)]
pub struct Violation {
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
}

/// The outcome of linting one file.
#[derive(Debug, Default)]
pub struct FileOutcome {
    pub violations: Vec<Violation>,
    /// pragmas that suppressed at least one violation
    pub pragmas_used: usize,
}

/// The outcome of linting a whole tree.
#[derive(Debug, Default)]
pub struct Report {
    /// (path relative to the src root, violation)
    pub violations: Vec<(String, Violation)>,
    pub files: usize,
    pub pragmas_used: usize,
}

// ---------------------------------------------------------------------------
// lexer: split source into per-line code and comment streams
// ---------------------------------------------------------------------------

/// One source line after lexing: `code` has string/char-literal contents
/// blanked (structure retained), `comment` holds the text of any `//` or
/// `/* */` comment on the line.
struct Line {
    code: String,
    comment: String,
    in_test: bool,
}

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(b[i - 1])
}

/// If `b[j]` is the `r` of a raw-string opener (`r"`, `r#"`, ...), return
/// the number of `#`s; else None.
fn raw_hashes(b: &[char], j: usize) -> Option<usize> {
    let mut h = 0usize;
    let mut k = j + 1;
    while b.get(k) == Some(&'#') {
        h += 1;
        k += 1;
    }
    if b.get(k) == Some(&'"') {
        Some(h)
    } else {
        None
    }
}

fn lex(src: &str) -> Vec<Line> {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        Block(u32),
        Str,
        RawStr(usize),
        Char,
    }
    let b: Vec<char> = src.chars().collect();
    let mut st = St::Code;
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: false,
            });
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && b.get(i + 1) == Some(&'/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && b.get(i + 1) == Some(&'*') {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == 'r' && !prev_is_ident(&b, i) && raw_hashes(&b, i).is_some() {
                    let h = raw_hashes(&b, i).unwrap();
                    code.push('"');
                    code.push('"');
                    st = St::RawStr(h);
                    i += h + 2; // past r, the #s, and the opening quote
                } else if c == 'b'
                    && !prev_is_ident(&b, i)
                    && b.get(i + 1) == Some(&'r')
                    && raw_hashes(&b, i + 1).is_some()
                {
                    let h = raw_hashes(&b, i + 1).unwrap();
                    code.push('"');
                    code.push('"');
                    st = St::RawStr(h);
                    i += h + 3; // past b, r, the #s, and the opening quote
                } else if c == '\'' {
                    // lifetime (`'a`, `'_`) vs char literal (`'a'`, `'\n'`)
                    let n1 = b.get(i + 1).copied();
                    let n2 = b.get(i + 2).copied();
                    let lifetime = matches!(n1, Some(ch) if ch == '_' || ch.is_alphabetic())
                        && n2 != Some('\'');
                    if lifetime {
                        code.push('\'');
                        i += 1;
                    } else {
                        code.push('\'');
                        code.push(' ');
                        code.push('\'');
                        st = St::Char;
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                comment.push(c);
                i += 1;
            }
            St::Block(d) => {
                if c == '/' && b.get(i + 1) == Some(&'*') {
                    st = St::Block(d + 1);
                    i += 2;
                } else if c == '*' && b.get(i + 1) == Some(&'/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    // keep an escaped newline on its own line for numbering
                    i += if b.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && (0..h).all(|t| b.get(i + 1 + t) == Some(&'#')) {
                    st = St::Code;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
            St::Char => {
                if c == '\\' {
                    i += if b.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '\'' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(Line { code, comment, in_test: false });
    lines
}

/// Mark lines inside `#[cfg(test)]`-gated items (the conventional
/// `#[cfg(test)] mod tests { ... }`).  Heuristic: the attribute arms a
/// flag; the next `{` opens the exempt region, which closes with its
/// matching brace.  Known limit: a `#[cfg(test)]` on a brace-less item
/// (e.g. a `use`) would over-extend to the next braced item — the
/// convention in this tree is attribute-on-module only.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut open_at: Option<i64> = None;
    for line in lines.iter_mut() {
        let mut in_test = open_at.is_some() || pending;
        if open_at.is_none()
            && (line.code.contains("#[cfg(test)]") || line.code.contains("#[cfg(all(test"))
        {
            pending = true;
            in_test = true;
        }
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    if pending && open_at.is_none() {
                        open_at = Some(depth);
                        pending = false;
                        in_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if open_at == Some(depth) {
                        open_at = None;
                    }
                }
                _ => {}
            }
        }
        line.in_test = in_test;
    }
}

// ---------------------------------------------------------------------------
// pragmas
// ---------------------------------------------------------------------------

/// `Some(Ok(rule))` for a well-formed `lint: allow(rule, reason = "...")`,
/// `Some(Err(why))` for a malformed one, `None` when the comment is not a
/// pragma at all.
fn parse_pragma(comment: &str) -> Option<Result<Rule, String>> {
    let at = comment.find("lint:")?;
    let body = comment[at + 5..].trim_start();
    let body = match body.strip_prefix("allow(") {
        Some(r) => r,
        None => {
            return Some(Err(
                "pragma must be `lint: allow(<rule>, reason = \"...\")`".to_string()
            ))
        }
    };
    let (name, rest) = match body.split_once(',') {
        Some(p) => p,
        None => return Some(Err("pragma missing `, reason = \"...\"`".to_string())),
    };
    let rule = match Rule::from_id(name.trim()) {
        Some(r) => r,
        None => return Some(Err(format!("unknown lint rule `{}`", name.trim()))),
    };
    let rest = rest.trim_start();
    let rest = match rest.strip_prefix("reason") {
        Some(r) => r.trim_start(),
        None => return Some(Err("pragma missing `reason = \"...\"`".to_string())),
    };
    let rest = match rest.strip_prefix('=') {
        Some(r) => r.trim_start(),
        None => return Some(Err("pragma missing `= \"...\"` after `reason`".to_string())),
    };
    let rest = match rest.strip_prefix('"') {
        Some(r) => r,
        None => return Some(Err("pragma reason must be a quoted string".to_string())),
    };
    let reason = match rest.split_once('"') {
        Some((r, _)) => r,
        None => return Some(Err("pragma reason string is unterminated".to_string())),
    };
    if reason.trim().is_empty() {
        return Some(Err("pragma reason must be nonempty".to_string()));
    }
    Some(Ok(rule))
}

// ---------------------------------------------------------------------------
// scope predicates
// ---------------------------------------------------------------------------

/// Modules whose numerics must be bitwise reproducible (DESIGN.md §14).
fn is_determinism_critical(rel: &str) -> bool {
    rel.starts_with("embed/")
        || rel.starts_with("linalg/")
        || rel.starts_with("ann/")
        || rel.starts_with("coordinator/")
        || rel.starts_with("checkpoint/")
        || rel == "distributed/proto.rs"
        || rel == "data/shard.rs"
}

/// Files that parse untrusted input (wire frames, npy files, shard
/// manifests, HTTP requests, CLI args): a panic here is a crash an
/// attacker or a corrupt file can trigger.
fn is_untrusted_parser(rel: &str) -> bool {
    matches!(
        rel,
        "distributed/proto.rs" | "util/npy.rs" | "data/shard.rs" | "serve/http.rs" | "cli.rs"
    )
}

/// The byte-level subset of the parser files, where the computed-index ban
/// additionally applies (HTTP/CLI parse `&str` by splitting, not offsets).
fn is_byte_parser(rel: &str) -> bool {
    matches!(rel, "distributed/proto.rs" | "util/npy.rs" | "data/shard.rs")
}

/// Instrumented service modules where obs is the one sanctioned telemetry
/// sink: anything they time must come from `util::clock::Stopwatch`, so
/// the telemetry-on/off A/B gate (DESIGN.md §15) covers every clock read.
/// Determinism-critical files are excluded only to avoid double-flagging —
/// `det_time` already bans the same tokens there.
fn is_obs_sink(rel: &str) -> bool {
    (rel.starts_with("serve/") || rel.starts_with("distributed/") || rel.starts_with("obs/"))
        && !is_determinism_critical(rel)
}

// ---------------------------------------------------------------------------
// token scanning helpers
// ---------------------------------------------------------------------------

/// Find `needle` in `hay` at identifier boundaries.  Boundary checks only
/// apply on sides where the needle itself starts/ends with an identifier
/// char (so `assert!` rejects `debug_assert!` on the left but doesn't
/// constrain what follows the `!`).
fn find_token(hay: &str, needle: &str) -> bool {
    let needs_before = needle.chars().next().map_or(false, is_ident_char);
    let needs_after = needle.chars().next_back().map_or(false, is_ident_char);
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(needle) {
        let at = from + p;
        let before_ok = !needs_before
            || at == 0
            || !is_ident_char(hay[..at].chars().next_back().unwrap_or(' '));
        let after_ok = !needs_after
            || hay[at + needle.len()..]
                .chars()
                .next()
                .map_or(true, |c| !is_ident_char(c));
        if before_ok && after_ok {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// Collect the text of a parenthesized call span starting at the `(` at
/// byte offset `col` of line `ln`, following up to 50 continuation lines.
fn paren_span(lines: &[Line], ln: usize, col: usize) -> String {
    let mut out = String::new();
    let mut depth = 0i32;
    for (off, line) in lines.iter().enumerate().skip(ln).take(50) {
        let code: &str = if off == ln { &line.code[col..] } else { &line.code };
        for ch in code.chars() {
            out.push(ch);
            match ch {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        return out;
                    }
                }
                _ => {}
            }
        }
        out.push('\n');
    }
    out
}

/// Is this index expression allowed in a byte parser?  Literal numbers and
/// SCREAMING_CASE consts (and ranges of those) index length-checked
/// headers; anything computed must go through `.get()`.
fn index_content_ok(content: &str) -> bool {
    fn literal_or_const(s: &str) -> bool {
        if s.is_empty() {
            return false;
        }
        let all_digits = s.chars().all(|c| c.is_ascii_digit() || c == '_');
        let first_upper = s.chars().next().map_or(false, |c| c.is_ascii_uppercase());
        let all_const =
            s.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_');
        all_digits || (first_upper && all_const)
    }
    let c = content.trim();
    if let Some((a, b)) = c.split_once("..") {
        let b = b.strip_prefix('=').unwrap_or(b).trim();
        let a = a.trim();
        (a.is_empty() || literal_or_const(a)) && (b.is_empty() || literal_or_const(b))
    } else {
        literal_or_const(c)
    }
}

/// True when the `.unwrap()` at byte offset `p` is the allowed lock-poison
/// idiom (`.lock().unwrap()` etc.): poisoning is a programmer-error
/// propagation, not attacker-reachable input handling.
fn is_poison_unwrap(code: &str, p: usize) -> bool {
    let head = &code[..p];
    head.ends_with(".lock()") || head.ends_with(".read()") || head.ends_with(".write()")
}

/// All byte offsets of `needle` in `hay`.
fn occurrences(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(needle) {
        out.push(from + p);
        from = from + p + needle.len();
    }
    out
}

// ---------------------------------------------------------------------------
// the rules
// ---------------------------------------------------------------------------

/// Does the `unsafe` on line `ln` have an immediately preceding (or
/// same-line) `// SAFETY:` comment?  The upward walk skips contiguous
/// comment lines, attribute lines, and chained `unsafe impl ... {}` lines
/// (one SAFETY block may justify a Send+Sync pair); a blank line or any
/// other code breaks adjacency.
fn has_safety_comment(lines: &[Line], ln: usize) -> bool {
    if lines[ln].comment.contains("SAFETY:") {
        return true;
    }
    let mut i = ln;
    while i > 0 {
        i -= 1;
        let l = &lines[i];
        let code = l.code.trim();
        if code.is_empty() && l.comment.is_empty() {
            return false; // blank line
        }
        let skippable = code.is_empty()
            || code.starts_with("#[")
            || (code.contains("unsafe impl") && code.ends_with("{}"));
        if !skippable {
            return false;
        }
        if l.comment.contains("SAFETY:") {
            return true;
        }
    }
    false
}

/// Lint one file's source.  `rel` is the path relative to the src root
/// with `/` separators (it selects which rule groups apply).
pub fn lint_source(rel: &str, src: &str) -> FileOutcome {
    let mut lines = lex(src);
    mark_test_regions(&mut lines);

    let critical = is_determinism_critical(rel);
    let parser = is_untrusted_parser(rel);
    let byte_parser = is_byte_parser(rel);
    let obs_sink = is_obs_sink(rel);

    let mut raw: Vec<Violation> = Vec::new();
    let mut pragmas: Vec<(usize, Rule)> = Vec::new(); // (0-based line, rule)

    for (ln, line) in lines.iter().enumerate() {
        match parse_pragma(&line.comment) {
            Some(Ok(rule)) => pragmas.push((ln, rule)),
            Some(Err(why)) => raw.push(Violation { line: ln + 1, rule: Rule::Pragma, msg: why }),
            None => {}
        }

        let code = &line.code;

        // (a) SAFETY comments, everywhere (tests included)
        if find_token(code, "unsafe") && !has_safety_comment(&lines, ln) {
            raw.push(Violation {
                line: ln + 1,
                rule: Rule::SafetyComment,
                msg: "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            });
        }

        // (b) tie contract, everywhere
        if code.contains(".partial_cmp(") && !code.contains("fn partial_cmp") {
            raw.push(Violation {
                line: ln + 1,
                rule: Rule::PartialCmp,
                msg: "`partial_cmp` breaks the tie contract on NaN — use `total_cmp` or a \
                      derived total order"
                    .to_string(),
            });
        }
        for needle in [".sort_by(", ".sort_unstable_by("] {
            if let Some(p) = code.find(needle) {
                let span = paren_span(&lines, ln, p + needle.len() - 1);
                let total = span.contains("total_cmp")
                    || span.contains(".cmp(")
                    || span.contains("cmp::")
                    || span.contains("Reverse(");
                if !total {
                    raw.push(Violation {
                        line: ln + 1,
                        rule: Rule::FloatSort,
                        msg: format!(
                            "`{}` without a total-order comparator (`total_cmp`, `Ord::cmp`, \
                             `Reverse`)",
                            needle.trim_start_matches('.').trim_end_matches('(')
                        ),
                    });
                }
            }
        }

        // (c) determinism-critical modules, non-test code only
        if critical && !line.in_test {
            if code.contains("Instant::now") || find_token(code, "SystemTime") {
                raw.push(Violation {
                    line: ln + 1,
                    rule: Rule::DetTime,
                    msg: "direct clock read in a determinism-critical module — route through \
                          `util::clock` (deadlines/telemetry only)"
                        .to_string(),
                });
            }
            if find_token(code, "HashMap") || find_token(code, "HashSet") {
                raw.push(Violation {
                    line: ln + 1,
                    rule: Rule::DetHash,
                    msg: "HashMap/HashSet in a determinism-critical module (iteration order is \
                          nondeterministic) — use BTreeMap/BTreeSet or a sorted Vec"
                        .to_string(),
                });
            }
            if code.contains("thread::current") || find_token(code, "ThreadId") {
                raw.push(Violation {
                    line: ln + 1,
                    rule: Rule::DetThread,
                    msg: "thread-identity read in a determinism-critical module".to_string(),
                });
            }
        }

        // (f) raw SIMD outside the audited kernel module, everywhere
        // (tests included).  Plain `contains` on purpose: intrinsic names
        // like `_mm256_add_ps` must match the `_mm256_` needle, which
        // ident-boundary matching would reject.
        if rel != "linalg/simd.rs" {
            for needle in
                ["std::arch", "core::arch", "_mm_", "_mm256_", "_mm512_", "target_feature"]
            {
                if code.contains(needle) {
                    raw.push(Violation {
                        line: ln + 1,
                        rule: Rule::SimdArch,
                        msg: format!(
                            "raw SIMD (`{needle}`) outside `linalg/simd.rs` — the dispatch and \
                             bitwise-equivalence contract lives there (DESIGN.md §16)"
                        ),
                    });
                }
            }
        }

        // (e) instrumented service modules, non-test code only
        if obs_sink
            && !line.in_test
            && (code.contains("Instant::now") || find_token(code, "SystemTime"))
        {
            raw.push(Violation {
                line: ln + 1,
                rule: Rule::ObsSink,
                msg: "direct clock read in an obs-sink module — time through \
                      `util::clock::Stopwatch` so the telemetry A/B gate covers it"
                    .to_string(),
            });
        }

        // (d) untrusted-input parsers, non-test code only
        if parser && !line.in_test {
            for p in occurrences(code, ".unwrap()") {
                if !is_poison_unwrap(code, p) {
                    raw.push(Violation {
                        line: ln + 1,
                        rule: Rule::ParserPanic,
                        msg: "`.unwrap()` in an untrusted-input parser — return an Err"
                            .to_string(),
                    });
                }
            }
            if code.contains(".expect(") {
                raw.push(Violation {
                    line: ln + 1,
                    rule: Rule::ParserPanic,
                    msg: "`.expect(...)` in an untrusted-input parser — return an Err"
                        .to_string(),
                });
            }
            for mac in
                ["panic!", "unreachable!", "todo!", "unimplemented!", "assert!", "assert_eq!",
                 "assert_ne!"]
            {
                if find_token(code, mac) {
                    raw.push(Violation {
                        line: ln + 1,
                        rule: Rule::ParserPanic,
                        msg: format!("`{mac}` in an untrusted-input parser — return an Err"),
                    });
                }
            }
        }
        if byte_parser && !line.in_test {
            let chars: Vec<char> = code.chars().collect();
            for (j, &ch) in chars.iter().enumerate() {
                if ch != '[' || j == 0 {
                    continue;
                }
                let p = chars[j - 1];
                let indexing = p == ']' || p == ')' || p == '?' || is_ident_char(p);
                if !indexing {
                    continue;
                }
                // matching `]` on the same line
                let mut depth = 0i32;
                let mut end = None;
                for (t, &c2) in chars.iter().enumerate().skip(j) {
                    match c2 {
                        '[' => depth += 1,
                        ']' => {
                            depth -= 1;
                            if depth == 0 {
                                end = Some(t);
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                let content: String = match end {
                    Some(e) => chars[j + 1..e].iter().collect(),
                    None => String::new(), // multi-line index: flag it
                };
                if end.is_none() || !index_content_ok(&content) {
                    raw.push(Violation {
                        line: ln + 1,
                        rule: Rule::ParserIndex,
                        msg: format!(
                            "computed slice index `[{}]` in a byte parser — use `.get()` with \
                             an error",
                            content.trim()
                        ),
                    });
                }
            }
        }
    }

    // pragma suppression: a pragma covers its own line and the next line
    let mut used = vec![false; pragmas.len()];
    let mut violations: Vec<Violation> = Vec::new();
    for v in raw {
        let l0 = v.line - 1;
        let mut suppressed = false;
        for (pi, &(pl, pr)) in pragmas.iter().enumerate() {
            if pr == v.rule && (pl == l0 || pl + 1 == l0) {
                used[pi] = true;
                suppressed = true;
                break;
            }
        }
        if !suppressed {
            violations.push(v);
        }
    }
    let pragmas_used = used.iter().filter(|u| **u).count();
    for (pi, &(pl, pr)) in pragmas.iter().enumerate() {
        if !used[pi] {
            violations.push(Violation {
                line: pl + 1,
                rule: Rule::Pragma,
                msg: format!("unused lint pragma for `{}` — remove it", pr.id()),
            });
        }
    }
    violations.sort_by_key(|v| v.line);
    FileOutcome { violations, pragmas_used }
}

// ---------------------------------------------------------------------------
// tree walking
// ---------------------------------------------------------------------------

fn collect_rs(root: &Path, rel: PathBuf, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        std::fs::read_dir(root.join(&rel))?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let r = rel.join(e.file_name());
        if e.file_type()?.is_dir() {
            collect_rs(root, r, out)?;
        } else if r.extension().map_or(false, |x| x == "rs") {
            out.push(r);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `src_root` (deterministic order).
pub fn lint_tree(src_root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(src_root, PathBuf::new(), &mut files)?;
    let mut report = Report::default();
    for rel in files {
        let src = std::fs::read_to_string(src_root.join(&rel))?;
        let rel_str = rel
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let out = lint_source(&rel_str, &src);
        report.files += 1;
        report.pragmas_used += out.pragmas_used;
        for v in out.violations {
            report.violations.push((rel_str.clone(), v));
        }
    }
    Ok(report)
}
