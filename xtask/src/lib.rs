//! Repo automation for the NOMAD workspace.  The only subcommand today is
//! the invariant linter (`cargo run -p xtask -- lint`); see [`lint`] and
//! DESIGN.md §14.

pub mod lint;
