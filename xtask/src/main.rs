//! `cargo run -p xtask -- lint [src-root ...]`
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage/IO error.

use std::path::{Path, PathBuf};

fn default_src_root() -> PathBuf {
    // xtask/ sits next to rust/ at the workspace root
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask has a parent directory")
        .join("rust")
        .join("src")
}

fn run_lint(roots: &[PathBuf]) -> i32 {
    let mut total_violations = 0usize;
    let mut total_files = 0usize;
    let mut total_pragmas = 0usize;
    for root in roots {
        let report = match xtask::lint::lint_tree(root) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("xtask lint: cannot walk {}: {e}", root.display());
                return 2;
            }
        };
        for (rel, v) in &report.violations {
            println!("{}/{}:{}: [{}] {}", root.display(), rel, v.line, v.rule.id(), v.msg);
        }
        total_violations += report.violations.len();
        total_files += report.files;
        total_pragmas += report.pragmas_used;
    }
    println!(
        "xtask lint: {} violation(s), {} pragma suppression(s) across {} file(s)",
        total_violations, total_pragmas, total_files
    );
    if total_violations > 0 {
        1
    } else {
        0
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("lint") => {
            let roots: Vec<PathBuf> = if args.len() > 1 {
                args[1..].iter().map(PathBuf::from).collect()
            } else {
                vec![default_src_root()]
            };
            std::process::exit(run_lint(&roots));
        }
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [src-root ...]");
            std::process::exit(2);
        }
    }
}
