//! Device-count scaling (the paper's Fig 3 "multiple GPUs" claim):
//! wall/modeled epoch time, communication volume, and quality as the
//! simulated device count grows.
//!
//! ```bash
//! cargo run --release --example multi_device_scaling -- [--n 8000]
//! ```

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::bench::{fmt_secs, Table};
use nomad::cli::Args;
use nomad::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use nomad::data::text_corpus_like;
use nomad::embed::NomadParams;
use nomad::harness::{evaluate, EvalCfg};
use nomad::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 8000);
    let epochs = args.usize("epochs", 60);

    let mut rng = Rng::new(2);
    let ds = text_corpus_like(n, &mut rng);
    println!("corpus: {} ({} x {})", ds.name, ds.n(), ds.dim());

    let eval_cfg = EvalCfg { np_sample: 250, triplets: 8000, ..Default::default() };
    let mut table = Table::new(
        "Multi-device scaling (modeled H100 node; measured on 1 CPU core)",
        &["Devices", "Measured", "Modeled", "Modeled speedup", "All-gather", "NP@10", "RTA"],
    );

    let mut base_modeled = None;
    for devices in [1usize, 2, 4, 8] {
        let params = NomadParams { epochs, ..Default::default() };
        let run_cfg = RunConfig {
            n_devices: devices,
            backend: BackendKind::Native,
            index: IndexParams { n_clusters: 64, ..Default::default() },
            ..Default::default()
        };
        let coord = NomadCoordinator::new(params, run_cfg);
        let run = coord.fit(&ds, &NativeBackend::default());
        let (np, rta) = evaluate(&ds, &run.positions, &eval_cfg);
        let base = *base_modeled.get_or_insert(run.modeled_train_secs);
        table.row(vec![
            format!("{devices}").into(),
            fmt_secs(run.train_secs).into(),
            fmt_secs(run.modeled_train_secs).into(),
            format!("{:.2}x", base / run.modeled_train_secs.max(1e-12)).into(),
            format!("{:.0} KiB", run.comm.allgather_bytes_total as f64 / 1024.0).into(),
            format!("{:.1}%", np * 100.0).into(),
            format!("{:.1}%", rta * 100.0).into(),
        ]);
    }
    table.print();
    table.save_json("multi_device_scaling_example");
}
