//! Noise-mass (|M|) sensitivity sweep on the PubMed-like corpus: the paper
//! leaves |M| unspecified; this probe motivates the repo default (50).

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::coordinator::{NomadCoordinator, RunConfig};
use nomad::data::pubmed_like;
use nomad::embed::NomadParams;
use nomad::harness::{evaluate, EvalCfg};
use nomad::util::rng::Rng;
fn main() {
    let mut rng = Rng::new(0);
    let ds = pubmed_like(8000, &mut rng);
    let eval_cfg = EvalCfg { np_sample: 250, triplets: 4000, ..Default::default() };
    let cases: Vec<(&str, NomadParams)> = vec![
        ("base m=5 revrank", NomadParams { epochs: 300, ..Default::default() }),
        ("m=20", NomadParams { epochs: 300, m_noise: 20.0, ..Default::default() }),
        ("m=50", NomadParams { epochs: 300, m_noise: 50.0, ..Default::default() }),
        ("m=100", NomadParams { epochs: 300, m_noise: 100.0, ..Default::default() }),
        ("m=50 e600", NomadParams { epochs: 600, m_noise: 50.0, ..Default::default() }), ("m=50 negs32", NomadParams { epochs: 300, m_noise: 50.0, negs: 32, ..Default::default() }),
    ];
    for (name, p) in cases {
        let k = p.k;
        let coord = NomadCoordinator::new(p, RunConfig {
            n_devices: 8,
            index: IndexParams { n_clusters: 48, k, ..Default::default() },
            ..Default::default()
        });
        let run = coord.fit(&ds, &NativeBackend::default());
        let (np, rta) = evaluate(&ds, &run.positions, &eval_cfg);
        println!("{name}: NP@10={:.1}% RTA={:.1}% wall={:.2}s", np*100.0, rta*100.0, run.train_secs);
    }
}
