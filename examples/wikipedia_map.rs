//! End-to-end driver (paper Fig 1 + Fig 4 analog): build a complete data
//! map of a Multilingual-Wikipedia-like corpus on the full three-layer
//! stack — K-Means ANN index, sharded multi-device NOMAD training through
//! the AOT XLA artifacts, metric evaluation, and multiscale renders.
//!
//! ```bash
//! cargo run --release --example wikipedia_map -- [--n 20000] [--devices 8] [--native]
//! ```
//!
//! Outputs: out/wikipedia_map.png (global Fig 1), out/wikipedia_zoom{1,2}.png
//! (the Fig 4(b)/(c)-style magnifications), plus headline stats on stdout.
//! The run is recorded in EXPERIMENTS.md §Fig1/Fig4.

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::cli::Args;
use nomad::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use nomad::data::wikipedia_like;
use nomad::embed::NomadParams;
use nomad::harness::{evaluate, EvalCfg};
use nomad::metrics::label_knn_agreement;
use nomad::util::rng::Rng;
use nomad::viz::{density_map, png, View};
use std::path::Path;

fn main() -> nomad::util::error::Result<()> {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 20_000);
    let devices = args.usize("devices", 8);
    let epochs = args.usize("epochs", 120);
    let backend = if args.bool("native") { BackendKind::Native } else { BackendKind::Xla };

    println!("== Multilingual-Wikipedia-like data map (Fig 1 / Fig 4 analog) ==");
    let mut rng = Rng::new(args.u64("seed", 1));
    let ds = wikipedia_like(n, &mut rng);
    println!(
        "corpus: {} ({} x {}), 3-level hierarchy: {} languages / {} topics / {} article clusters",
        ds.name,
        ds.n(),
        ds.dim(),
        ds.labels[0].iter().max().unwrap() + 1,
        ds.labels[1].iter().max().unwrap() + 1,
        ds.labels[2].iter().max().unwrap() + 1,
    );

    let params = NomadParams { epochs, ..Default::default() };
    let run_cfg = RunConfig {
        n_devices: devices,
        backend,
        index: IndexParams { n_clusters: 64, ..Default::default() },
        verbose: true,
        ..Default::default()
    };
    let coord = NomadCoordinator::new(params, run_cfg);
    let run = coord.fit(&ds, &NativeBackend::default());

    println!(
        "\nindex: {} clusters, {:.1}s | train: {:.1}s measured ({} sim devices, 1 core), {:.3}s modeled-8xH100",
        run.n_clusters, run.index_secs, run.train_secs, devices, run.modeled_train_secs
    );
    println!(
        "comm: {:.1} KiB means all-gathered over {} epochs; positive phase: 0 bytes",
        run.comm.allgather_bytes_total as f64 / 1024.0,
        run.comm.epochs
    );

    let eval_cfg = EvalCfg { np_sample: 300, triplets: 20_000, ..Default::default() };
    let (np10, rta) = evaluate(&ds, &run.positions, &eval_cfg);
    let mut mrng = Rng::new(9);
    let lang_purity = label_knn_agreement(&run.positions, &ds.labels[0], 2000, &mut mrng);
    let article_purity = label_knn_agreement(&run.positions, ds.fine_labels(), 2000, &mut mrng);
    println!("quality: NP@10 = {:.1}%  RTA = {:.1}%", np10 * 100.0, rta * 100.0);
    println!(
        "map coherence: language-level 1-NN purity {:.1}%, article-cluster purity {:.1}%",
        lang_purity * 100.0,
        article_purity * 100.0
    );

    // ---- Fig 1: global map colored by language -------------------------
    std::fs::create_dir_all("out")?;
    let view = View::fit(&run.positions);
    let global = density_map(&run.positions, Some(&ds.labels[0]), &view, 1000, 1000);
    png::write_rgb(Path::new("out/wikipedia_map.png"), global.width, global.height, &global.pixels)?;

    // ---- Fig 4: multiscale zooms around the densest article cluster ----
    // find the largest fine cluster's centroid in embedding space
    let fine = ds.fine_labels();
    let n_fine = (*fine.iter().max().unwrap() + 1) as usize;
    let mut counts = vec![0u32; n_fine];
    for &l in fine {
        counts[l as usize] += 1;
    }
    let target = counts.iter().enumerate().max_by_key(|(_, c)| **c).unwrap().0 as u32;
    let mut cx = 0.0f64;
    let mut cy = 0.0f64;
    let mut m = 0.0f64;
    for i in 0..ds.n() {
        if fine[i] == target {
            cx += run.positions.row(i)[0] as f64;
            cy += run.positions.row(i)[1] as f64;
            m += 1.0;
        }
    }
    let (cx, cy) = ((cx / m) as f32, (cy / m) as f32);
    for (file, factor, level) in [
        ("out/wikipedia_zoom1.png", 20.0, 1usize), // Fig 4(b): 20x, topic colors
        ("out/wikipedia_zoom2.png", 100.0, 2),     // Fig 4(c): deeper, article colors
    ] {
        let z = view.zoom(cx, cy, factor);
        let r = density_map(&run.positions, Some(&ds.labels[level]), &z, 800, 800);
        png::write_rgb(Path::new(file), r.width, r.height, &r.pixels)?;
    }
    println!("renders: out/wikipedia_map.png, out/wikipedia_zoom1.png, out/wikipedia_zoom2.png");

    // machine-readable record for EXPERIMENTS.md
    use nomad::bench::jsonx::*;
    nomad::bench::log_experiment(
        "fig1_fig4_wikipedia",
        obj(vec![
            ("n", num(n as f64)),
            ("devices", num(devices as f64)),
            ("epochs", num(epochs as f64)),
            ("np10", num(np10)),
            ("rta", num(rta)),
            ("lang_purity", num(lang_purity)),
            ("article_purity", num(article_purity)),
            ("train_secs", num(run.train_secs)),
            ("modeled_secs", num(run.modeled_train_secs)),
            ("allgather_bytes", num(run.comm.allgather_bytes_total as f64)),
        ]),
    );
    Ok(())
}
