//! Table 1 analog: PubMed-like corpus, NOMAD vs OpenTSNE-like vs the
//! single-GPU baselines, reporting NP@10, wall time, modeled time, speedup.
//!
//! ```bash
//! cargo run --release --example pubmed_table1 -- [--n 10000] [--seeds 3]
//! ```
//!
//! The paper's Table 1: OpenTSNE 6.2% NP@10 in 8 h on 16 CPUs; NOMAD
//! 6.1±0.3% in 1.47 h on 8 H100s (5.4x); RapidsUMAP / t-SNE-CUDA OOM.
//! Here the *shape* to reproduce is: NOMAD ≈ OpenTSNE quality, large
//! speedup, and the single-GPU baselines exceeding their (simulated)
//! memory budget.  See EXPERIMENTS.md §Table1.

use nomad::ann::IndexParams;
use nomad::bench::{fmt_pct, fmt_secs, Table};
use nomad::cli::Args;
use nomad::coordinator::BackendKind;
use nomad::data::pubmed_like;
use nomad::harness::{run_method, EvalCfg, Method};
use nomad::util::rng::Rng;
use nomad::util::stats::Summary;

/// Simulated single-GPU memory budget (bytes) for the OOM column: both
/// t-SNE-CUDA and RapidsUMAP materialize O(n·k) + O(n²/partition) device
/// state; the paper hit 80 GB caps at PubMed scale.  We scale the cap to
/// this testbed so the same *mechanism* (single-device memory wall vs
/// NOMAD's sharding) is exercised.
fn single_gpu_oom(n: usize, dim: usize, budget_bytes: usize) -> bool {
    // embeddings + kNN graph + per-point force scratch, f32
    let per_point = dim * 4 + 90 * 4 + 64;
    n * per_point > budget_bytes
}

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 10_000);
    let seeds = args.u64("seeds", 3);
    let epochs = args.usize("epochs", 120);
    let budget = args.usize("gpu-bytes", 8 * 1024 * 1024); // scaled-down "vRAM"

    let mut rng = Rng::new(0);
    let ds = pubmed_like(n, &mut rng);
    println!("corpus: {} ({} x {})", ds.name, ds.n(), ds.dim());
    let index = IndexParams { n_clusters: 48, ..Default::default() };
    let eval_cfg = EvalCfg { np_sample: 300, triplets: 10_000, ..Default::default() };

    let mut table = Table::new(
        "Table 1 analog — PubMed-like corpus",
        &["Method", "Compute", "NP@10", "Time", "Modeled", "Speedup"],
    );

    // OpenTSNE row (the 1x reference)
    let mut open_np = Vec::new();
    let mut open_secs = Vec::new();
    for seed in 0..seeds {
        let r = run_method(&ds, &Method::OpenTsneLike, epochs * 2, 0, &index, &eval_cfg, seed);
        open_np.push(r.quality[0].np_at_10);
        open_secs.push(r.total_secs);
    }
    let open_np_s = Summary::of(&open_np);
    let open_time = Summary::of(&open_secs).mean;
    table.row(vec![
        "OpenTSNE-like".into(),
        "1 core (CPU)".into(),
        fmt_pct(open_np_s.mean, open_np_s.sem()).into(),
        fmt_secs(open_time).into(),
        "-".into(),
        "1x".into(),
    ]);

    // NOMAD rows
    let mut nomad_np = Vec::new();
    let mut nomad_secs = Vec::new();
    let mut nomad_modeled = Vec::new();
    for seed in 0..seeds {
        let r = run_method(
            &ds,
            &Method::Nomad { devices: 8, backend: BackendKind::Xla },
            epochs,
            0,
            &index,
            &eval_cfg,
            seed,
        );
        nomad_np.push(r.quality[0].np_at_10);
        nomad_secs.push(r.total_secs);
        nomad_modeled.push(r.modeled_secs);
    }
    let np_s = Summary::of(&nomad_np);
    let t = Summary::of(&nomad_secs).mean;
    let tm = Summary::of(&nomad_modeled).mean;
    table.row(vec![
        "NOMAD Projection".into(),
        "8 sim-dev (XLA)".into(),
        fmt_pct(np_s.mean, np_s.sem()).into(),
        fmt_secs(t).into(),
        fmt_secs(tm).into(),
        format!("{:.1}x (modeled)", open_time / tm.max(1e-9)).into(),
    ]);

    // single-GPU baselines: exercised at reduced n, reported OOM at full n
    for (name, method) in [
        ("RapidsUMAP-like", Method::UmapLike),
        ("tSNE-CUDA-like", Method::TsneCudaLike),
    ] {
        if single_gpu_oom(n, ds.dim(), budget) {
            table.row(vec![
                name.into(),
                "1 sim-GPU".into(),
                "-".into(),
                "OOM".into(),
                "-".into(),
                "-".into(),
            ]);
        } else {
            let r = run_method(&ds, &method, epochs, 0, &index, &eval_cfg, 0);
            let cp = &r.quality[0];
            table.row(vec![
                name.into(),
                "1 sim-GPU".into(),
                fmt_pct(cp.np_at_10, 0.0).into(),
                fmt_secs(r.total_secs).into(),
                "-".into(),
                "-".into(),
            ]);
        }
    }

    table.print();
    table.save_json("table1_pubmed_example");
    println!("\n(paper: OpenTSNE 6.2% / 8h; NOMAD 6.1±0.3% / 1.47h / 5.4x; others OOM)");
}
