//! Quickstart: embed a synthetic 10-cluster corpus with NOMAD Projection,
//! report quality metrics, and render the map.
//!
//! ```bash
//! cargo run --release --example quickstart -- [--n 4000] [--devices 2] [--threads 4] [--xla]
//! ```

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::cli::Args;
use nomad::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use nomad::data::gaussian_mixture;
use nomad::embed::NomadParams;
use nomad::harness::{evaluate, EvalCfg};
use nomad::util::rng::Rng;
use nomad::viz::{density_map, png, View};

fn main() -> nomad::util::error::Result<()> {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 4000);
    let devices = args.usize("devices", 2);
    let backend = if args.bool("xla") { BackendKind::Xla } else { BackendKind::Native };

    println!("== NOMAD Projection quickstart ==");
    let mut rng = Rng::new(args.u64("seed", 0));
    let ds = gaussian_mixture(n, 64, 10, 9.0, 0.4, 0.8, &mut rng);
    println!("dataset: {} ({} x {})", ds.name, ds.n(), ds.dim());

    let params = NomadParams { epochs: args.usize("epochs", 150), ..Default::default() };
    let run_cfg = RunConfig {
        n_devices: devices,
        backend,
        index: IndexParams { n_clusters: 16, ..Default::default() },
        verbose: true,
        ..Default::default()
    };
    let coord = NomadCoordinator::new(params, run_cfg);
    let run = coord.fit(&ds, &NativeBackend::default());

    println!(
        "index: {} clusters in {:.2}s | train: {:.2}s measured, {:.3}s modeled-H100 ({} devices)",
        run.n_clusters, run.index_secs, run.train_secs, run.modeled_train_secs, devices
    );
    println!(
        "comm: {} bytes all-gathered total, 0 bytes during positive-force phase",
        run.comm.allgather_bytes_total
    );

    let (np10, rta) = evaluate(&ds, &run.positions, &EvalCfg::default());
    println!("quality: NP@10 = {:.1}%  RTA = {:.1}%", np10 * 100.0, rta * 100.0);

    std::fs::create_dir_all("out")?;
    let view = View::fit(&run.positions);
    let raster = density_map(&run.positions, Some(ds.fine_labels()), &view, 800, 800);
    png::write_rgb(std::path::Path::new("out/quickstart_map.png"), raster.width, raster.height, &raster.pixels)?;
    println!("map written to out/quickstart_map.png");
    Ok(())
}
